//! The typed residual tape: slots are **minted at model build time**
//! (one [`SlotId`] per residual the composition will save), the forward
//! pass pushes them strictly in mint order through a [`TapeWriter`], and
//! the backward pass consumes them strictly in reverse through a
//! [`TapeReader`].
//!
//! Because a layer stores the *same* `SlotId` fields that drive both its
//! `fwd` pushes and its `bwd` pops, push/pop symmetry is enforced by
//! construction: a desynchronized layer cannot silently mis-slice the
//! residual stream — the writer/reader cursors reject any out-of-order
//! slot with a named error. The flattened slot list (the *tape schema*)
//! is therefore the single source of truth for the residual ABI: the
//! manifest residual section, the measured-memory accounting, and the
//! fwd output arity are all derived from it (see `spec::build_manifest`).

use anyhow::{ensure, Result};

use super::super::arena::Arena;
use crate::runtime::tensor::{DType, Tensor};

/// Residual category — the Figure 2 breakdown axis. String forms match
/// the manifest `kind` field emitted by the Python exporter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Normalized input x̂ of a plain LN/RMS norm.
    NormInput,
    /// Shared x̂ of an MS-LN/MS-RMS norm (also serves the next linears).
    NormShared,
    /// Per-row 1/σ (LN) or 1/rms (RMSNorm).
    NormStat,
    /// Input a linear needs for its weight/LoRA-A gradient.
    LinearInput,
    /// LoRA intermediate `u = x·Aᵀ`.
    LoraU,
    /// Saved q/k/v (attention probabilities are recomputed in bwd).
    AttnQkv,
    /// Full-precision pre-activation (exact GELU/SiLU backward).
    ActFull,
    /// Packed activation codes (2-bit ReGELU2/ReSiLU2, 1-bit ReLU).
    ActCodes,
    /// SwiGLU gate-multiply operand (`act(u₁)` or `u₃` — both factors
    /// are needed by the product rule).
    GateOperand,
    /// Classifier/LM head input (pooled or per-token).
    HeadInput,
    /// Logits kept for the CE backward.
    Logits,
    /// Gradient-checkpointing block input (everything else recomputed).
    CkptInput,
}

impl Kind {
    /// Manifest string form.
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::NormInput => "norm_input",
            Kind::NormShared => "norm_shared",
            Kind::NormStat => "norm_stat",
            Kind::LinearInput => "linear_input",
            Kind::LoraU => "lora_u",
            Kind::AttnQkv => "attn_qkv",
            Kind::ActFull => "act_full",
            Kind::ActCodes => "act_codes",
            Kind::GateOperand => "gate_operand",
            Kind::HeadInput => "head_input",
            Kind::Logits => "logits",
            Kind::CkptInput => "ckpt_input",
        }
    }
}

/// A tape slot token. Minted by [`Composer::slot`] in forward push
/// order; its index doubles as the residual's position in the fwd
/// output list, so `reader.get(slot)` is O(1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotId(pub(crate) usize);

impl SlotId {
    /// Position of this slot's tensor in the residual list.
    pub fn index(self) -> usize {
        self.0
    }
}

/// Static description of one residual: everything the manifest needs,
/// known at build time (shapes are fixed by the config).
#[derive(Debug, Clone)]
pub struct SlotInfo {
    /// Producing module path (e.g. `block0.attn.q`).
    pub module: String,
    /// Residual category.
    pub kind: Kind,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Storage dtype.
    pub dtype: DType,
    /// Effective bits per *logical* element (2.0 for 2-bit codes, 1.0
    /// for 1-bit sign codes, 8·dtype size otherwise).
    pub bits_per_elem: f64,
}

impl SlotInfo {
    /// Stored bytes of this residual.
    pub fn bytes(&self) -> u64 {
        (self.shape.iter().product::<usize>() * self.dtype.size()) as u64
    }
}

/// Mints [`SlotId`]s at build time. One composer per tape: the model has
/// one for its top-level schema, and every [`CkptBlock`] has a private
/// one for the inner residuals it recomputes instead of storing.
///
/// [`CkptBlock`]: super::CkptBlock
#[derive(Default)]
pub struct Composer {
    slots: Vec<SlotInfo>,
}

impl Composer {
    /// An empty composer.
    pub fn new() -> Composer {
        Composer::default()
    }

    /// Mint the next slot. Layers must later push slots in exactly the
    /// mint order — the writer enforces it.
    pub fn slot(&mut self, module: &str, kind: Kind, shape: &[usize],
                dtype: DType, bits_per_elem: f64) -> SlotId {
        self.slots.push(SlotInfo {
            module: module.to_string(),
            kind,
            shape: shape.to_vec(),
            dtype,
            bits_per_elem,
        });
        SlotId(self.slots.len() - 1)
    }

    /// f32 slot with the default 32 bits/elem.
    pub fn slot_f32(&mut self, module: &str, kind: Kind,
                    shape: &[usize]) -> SlotId {
        self.slot(module, kind, shape, DType::F32, 32.0)
    }

    /// The finished schema, in push order.
    pub fn finish(self) -> Vec<SlotInfo> {
        self.slots
    }
}

/// Forward-pass tape: collects residual tensors, checking every push
/// against the schema (order, shape, dtype).
pub struct TapeWriter<'a> {
    schema: &'a [SlotInfo],
    out: Vec<Tensor>,
}

impl<'a> TapeWriter<'a> {
    /// A writer expecting exactly the slots of `schema`, in order.
    pub fn new(schema: &'a [SlotInfo]) -> TapeWriter<'a> {
        TapeWriter { schema, out: Vec::with_capacity(schema.len()) }
    }

    fn expect(&self, slot: SlotId) -> Result<&'a SlotInfo> {
        ensure!(
            slot.0 == self.out.len() && slot.0 < self.schema.len(),
            "tape push out of order: slot #{} ({}) pushed at position \
             {} of {}",
            slot.0,
            self.schema
                .get(slot.0)
                .map(|s| s.module.as_str())
                .unwrap_or("<foreign slot>"),
            self.out.len(),
            self.schema.len()
        );
        Ok(&self.schema[slot.0])
    }

    /// Push an f32 residual; the payload is copied into an arena-backed
    /// tensor.
    pub fn push_f32(&mut self, arena: &mut Arena, slot: SlotId,
                    v: &[f32]) -> Result<()> {
        let info = self.expect(slot)?;
        ensure!(info.dtype == DType::F32
                    && info.shape.iter().product::<usize>() == v.len(),
                "slot {}.{} expects f32 shape {:?}, got {} elems",
                info.module, info.kind.as_str(), info.shape, v.len());
        self.out.push(arena.tensor_from_f32(&info.shape, v));
        Ok(())
    }

    /// Push a u8 residual, taking ownership of an arena byte buffer
    /// (packed code planes are encoded straight into their payload).
    pub fn push_u8(&mut self, slot: SlotId, data: Vec<u8>) -> Result<()> {
        let info = self.expect(slot)?;
        ensure!(info.dtype == DType::U8
                    && info.shape.iter().product::<usize>() == data.len(),
                "slot {}.{} expects u8 shape {:?}, got {} bytes",
                info.module, info.kind.as_str(), info.shape, data.len());
        self.out.push(Tensor {
            shape: info.shape.clone(),
            dtype: DType::U8,
            data,
        });
        Ok(())
    }

    /// Finish the pass; errors unless every slot was pushed.
    pub fn finish(self) -> Result<Vec<Tensor>> {
        ensure!(
            self.out.len() == self.schema.len(),
            "forward pushed {} of {} tape slots",
            self.out.len(),
            self.schema.len()
        );
        Ok(self.out)
    }
}

/// Backward-pass tape over the residual list `fwd` produced: pops in
/// exact reverse push order (checked), with random-access [`get`] for
/// slots another layer owns (MS-norm sharing, attention's shared
/// linear input).
///
/// [`get`]: TapeReader::get
pub struct TapeReader<'a> {
    schema: &'a [SlotInfo],
    res: &'a [Tensor],
    top: usize,
}

impl<'a> TapeReader<'a> {
    /// A reader over `res`, which must match `schema` in arity.
    pub fn new(schema: &'a [SlotInfo],
               res: &'a [Tensor]) -> Result<TapeReader<'a>> {
        ensure!(
            res.len() == schema.len(),
            "residual list has {} tensors, tape schema has {}",
            res.len(),
            schema.len()
        );
        Ok(TapeReader { schema, res, top: res.len() })
    }

    /// Consume `slot`, which must be the next one in reverse order.
    pub fn pop(&mut self, slot: SlotId) -> Result<&'a Tensor> {
        ensure!(self.top > 0, "residual tape underflow");
        ensure!(
            slot.0 == self.top - 1,
            "tape pop out of order: slot #{} ({}) popped at top {}",
            slot.0,
            self.schema
                .get(slot.0)
                .map(|s| s.module.as_str())
                .unwrap_or("<foreign slot>"),
            self.top
        );
        let info = &self.schema[slot.0];
        self.top -= 1;
        let t = &self.res[slot.0];
        ensure!(t.dtype == info.dtype && t.shape == info.shape,
                "residual {}.{} does not match its slot: {:?} vs {:?}",
                info.module, info.kind.as_str(), t.shape, info.shape);
        Ok(t)
    }

    /// Read a not-yet-popped slot without consuming it (shared
    /// residuals: the owner pops it later, in its own reverse position).
    pub fn get(&self, slot: SlotId) -> Result<&'a Tensor> {
        ensure!(
            slot.0 < self.top,
            "tape get of popped or foreign slot #{} ({})",
            slot.0,
            self.schema
                .get(slot.0)
                .map(|s| s.module.as_str())
                .unwrap_or("<foreign slot>")
        );
        Ok(&self.res[slot.0])
    }

    /// Finish the pass; errors unless every slot was consumed.
    pub fn finish(self) -> Result<()> {
        ensure!(self.top == 0,
                "residual tape not fully consumed: {} slots left",
                self.top);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema2() -> Vec<SlotInfo> {
        let mut c = Composer::new();
        c.slot_f32("a", Kind::NormInput, &[2, 2]);
        c.slot("b", Kind::ActCodes, &[4], DType::U8, 2.0);
        c.finish()
    }

    #[test]
    fn push_pop_roundtrip_in_order() {
        let schema = schema2();
        let mut arena = Arena::new();
        let mut w = TapeWriter::new(&schema);
        w.push_f32(&mut arena, SlotId(0), &[1.0, 2.0, 3.0, 4.0]).unwrap();
        w.push_u8(SlotId(1), vec![9, 8, 7, 6]).unwrap();
        let res = w.finish().unwrap();
        let mut r = TapeReader::new(&schema, &res).unwrap();
        assert_eq!(r.get(SlotId(0)).unwrap().as_f32()[3], 4.0);
        assert_eq!(r.pop(SlotId(1)).unwrap().data, vec![9, 8, 7, 6]);
        assert_eq!(r.pop(SlotId(0)).unwrap().shape, vec![2, 2]);
        r.finish().unwrap();
    }

    #[test]
    fn out_of_order_push_is_rejected() {
        let schema = schema2();
        let mut w = TapeWriter::new(&schema);
        assert!(w.push_u8(SlotId(1), vec![0; 4]).is_err());
    }

    #[test]
    fn out_of_order_pop_and_stale_get_are_rejected() {
        let schema = schema2();
        let mut arena = Arena::new();
        let mut w = TapeWriter::new(&schema);
        w.push_f32(&mut arena, SlotId(0), &[0.0; 4]).unwrap();
        w.push_u8(SlotId(1), vec![0; 4]).unwrap();
        let res = w.finish().unwrap();
        let mut r = TapeReader::new(&schema, &res).unwrap();
        assert!(r.pop(SlotId(0)).is_err(), "must pop slot 1 first");
        r.pop(SlotId(1)).unwrap();
        assert!(r.get(SlotId(1)).is_err(), "slot 1 is consumed");
        r.pop(SlotId(0)).unwrap();
    }

    #[test]
    fn unfinished_passes_are_rejected() {
        let schema = schema2();
        let w = TapeWriter::new(&schema);
        assert!(w.finish().is_err());
        let res = vec![
            Tensor::from_f32(&[2, 2], &[0.0; 4]),
            Tensor::from_u8(&[4], &[0; 4]),
        ];
        let r = TapeReader::new(&schema, &res).unwrap();
        assert!(r.finish().is_err());
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let schema = schema2();
        let mut arena = Arena::new();
        let mut w = TapeWriter::new(&schema);
        assert!(w.push_f32(&mut arena, SlotId(0), &[0.0; 3]).is_err());
    }
}
