//! The typed residual tape: slots are **minted at model build time**
//! (one [`SlotId`] per residual the composition will save), the forward
//! pass pushes them strictly in mint order through a [`TapeWriter`], and
//! the backward pass consumes them strictly in reverse through a
//! [`TapeReader`].
//!
//! Because a layer stores the *same* `SlotId` fields that drive both its
//! `fwd` pushes and its `bwd` pops, push/pop symmetry is enforced by
//! construction: a desynchronized layer cannot silently mis-slice the
//! residual stream — the writer/reader cursors reject any out-of-order
//! slot with a named error. The flattened slot list (the *tape schema*)
//! is therefore the single source of truth for the residual ABI: the
//! manifest residual section, the measured-memory accounting, and the
//! fwd output arity are all derived from it (see `spec::build_manifest`).

use anyhow::{ensure, Result};

use super::super::arena::Arena;
use crate::quant::int8;
use crate::runtime::tensor::{DType, Tensor};

/// Residual category — the Figure 2 breakdown axis. String forms match
/// the manifest `kind` field emitted by the Python exporter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Normalized input x̂ of a plain LN/RMS norm.
    NormInput,
    /// Shared x̂ of an MS-LN/MS-RMS norm (also serves the next linears).
    NormShared,
    /// Per-row 1/σ (LN) or 1/rms (RMSNorm).
    NormStat,
    /// Input a linear needs for its weight/LoRA-A gradient.
    LinearInput,
    /// LoRA intermediate `u = x·Aᵀ`.
    LoraU,
    /// Saved q/k/v (attention probabilities are recomputed in bwd).
    AttnQkv,
    /// Full-precision pre-activation (exact GELU/SiLU backward).
    ActFull,
    /// Packed activation codes (2-bit ReGELU2/ReSiLU2, 1-bit ReLU).
    ActCodes,
    /// SwiGLU gate-multiply operand (`act(u₁)` or `u₃` — both factors
    /// are needed by the product rule).
    GateOperand,
    /// Classifier/LM head input (pooled or per-token).
    HeadInput,
    /// Logits kept for the CE backward.
    Logits,
    /// Gradient-checkpointing block input (everything else recomputed).
    CkptInput,
}

impl Kind {
    /// Manifest string form.
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::NormInput => "norm_input",
            Kind::NormShared => "norm_shared",
            Kind::NormStat => "norm_stat",
            Kind::LinearInput => "linear_input",
            Kind::LoraU => "lora_u",
            Kind::AttnQkv => "attn_qkv",
            Kind::ActFull => "act_full",
            Kind::ActCodes => "act_codes",
            Kind::GateOperand => "gate_operand",
            Kind::HeadInput => "head_input",
            Kind::Logits => "logits",
            Kind::CkptInput => "ckpt_input",
        }
    }

    /// Whether a Mesa (`_mesa`) composition stores this save as int8
    /// codes + scales instead of f32. The scope mirrors the paper's
    /// Mesa baseline decomposition (Mesa-GELU / Mesa-LN, Tables 1/7)
    /// and the memmodel's accounting: the *nonlinear-layer* saves —
    /// norm x̂ (plain or shared) and full-precision pre-activations.
    /// Attention q/k/v, standalone linear inputs, packed code planes,
    /// per-row stats, and the head stay in their native dtypes, which
    /// is what preserves the paper's `ours < mesa < baseline` ordering
    /// on the fp32 tape.
    pub fn mesa_quantized(self) -> bool {
        matches!(self,
                 Kind::NormInput | Kind::NormShared | Kind::ActFull)
    }
}

/// A tape slot token. Minted by [`Composer::slot`] in forward push
/// order; its index doubles as the residual's position in the fwd
/// output list, so `reader.get(slot)` is O(1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotId(pub(crate) usize);

impl SlotId {
    /// Position of this slot's tensor in the residual list.
    pub fn index(self) -> usize {
        self.0
    }
}

/// Static description of one residual: everything the manifest needs,
/// known at build time (shapes are fixed by the config).
#[derive(Debug, Clone)]
pub struct SlotInfo {
    /// Producing module path (e.g. `block0.attn.q`).
    pub module: String,
    /// Residual category.
    pub kind: Kind,
    /// *Stored* tensor shape. Equal to the logical shape except for
    /// packed storage: code planes pack their trailing dim, and int8
    /// slots store `qgroup + 4` bytes per group (codes + f32 scale).
    pub shape: Vec<usize>,
    /// Storage dtype.
    pub dtype: DType,
    /// Effective bits per *logical* element (2.0 for 2-bit codes, 1.0
    /// for 1-bit sign codes, `8 + 32/g` for int8 groups of `g`,
    /// 8·dtype size otherwise).
    pub bits_per_elem: f64,
    /// Mesa int8 quantization group (`Some(g)`: the slot stores groups
    /// of `g` int8 codes + a 4-byte f32 scale; pushed/popped as f32 —
    /// the tape codec quantizes and dequantizes at the boundary).
    pub qgroup: Option<usize>,
}

impl SlotInfo {
    /// Stored bytes of this residual.
    pub fn bytes(&self) -> u64 {
        (self.shape.iter().product::<usize>() * self.dtype.size()) as u64
    }
}

/// Mints [`SlotId`]s at build time. One composer per tape: the model has
/// one for its top-level schema, and every [`CkptBlock`] has a private
/// one for the inner residuals it recomputes instead of storing.
///
/// [`CkptBlock`]: super::CkptBlock
#[derive(Default)]
pub struct Composer {
    slots: Vec<SlotInfo>,
    mesa: bool,
}

impl Composer {
    /// An empty composer (no Mesa quantization).
    pub fn new() -> Composer {
        Composer::default()
    }

    /// A composer whose [`Kind::mesa_quantized`] f32 saves mint as
    /// per-group int8 slots (the `_mesa` preset axis).
    pub fn with_mesa(mesa: bool) -> Composer {
        Composer { slots: Vec::new(), mesa }
    }

    /// Mint the next slot, exactly as described. Layers must later push
    /// slots in exactly the mint order — the writer enforces it.
    pub fn slot(&mut self, module: &str, kind: Kind, shape: &[usize],
                dtype: DType, bits_per_elem: f64) -> SlotId {
        self.slots.push(SlotInfo {
            module: module.to_string(),
            kind,
            shape: shape.to_vec(),
            dtype,
            bits_per_elem,
            qgroup: None,
        });
        SlotId(self.slots.len() - 1)
    }

    /// f32 save of logical `shape`. Under a Mesa composer, eligible
    /// kinds (see [`Kind::mesa_quantized`]) mint as int8 group slots
    /// instead: group = the trailing dim, stored shape
    /// `[..., g + 4]` (codes + f32 scale per group), dtype int8,
    /// `8 + 32/g` bits per logical element.
    pub fn slot_f32(&mut self, module: &str, kind: Kind,
                    shape: &[usize]) -> SlotId {
        if self.mesa && kind.mesa_quantized() {
            let g = *shape.last().expect("quantized slot needs a shape");
            let mut stored = shape.to_vec();
            *stored.last_mut().unwrap() = g + int8::GROUP_FOOTER_BYTES;
            self.slots.push(SlotInfo {
                module: module.to_string(),
                kind,
                shape: stored,
                dtype: DType::I8,
                bits_per_elem: int8::bits_per_elem(g),
                qgroup: Some(g),
            });
            return SlotId(self.slots.len() - 1);
        }
        self.slot(module, kind, shape, DType::F32, 32.0)
    }

    /// The finished schema, in push order.
    pub fn finish(self) -> Vec<SlotInfo> {
        self.slots
    }
}

/// Forward-pass tape: collects residual tensors, checking every push
/// against the schema (order, shape, dtype).
pub struct TapeWriter<'a> {
    schema: &'a [SlotInfo],
    out: Vec<Tensor>,
}

impl<'a> TapeWriter<'a> {
    /// A writer expecting exactly the slots of `schema`, in order.
    pub fn new(schema: &'a [SlotInfo]) -> TapeWriter<'a> {
        TapeWriter { schema, out: Vec::with_capacity(schema.len()) }
    }

    fn expect(&self, slot: SlotId) -> Result<&'a SlotInfo> {
        ensure!(
            slot.0 == self.out.len() && slot.0 < self.schema.len(),
            "tape push out of order: slot #{} ({}) pushed at position \
             {} of {}",
            slot.0,
            self.schema
                .get(slot.0)
                .map(|s| s.module.as_str())
                .unwrap_or("<foreign slot>"),
            self.out.len(),
            self.schema.len()
        );
        Ok(&self.schema[slot.0])
    }

    /// Push an f32 residual; the payload is copied into an arena-backed
    /// tensor. For an int8 slot (`_mesa`), the fused group quantizer
    /// encodes straight into the arena-backed packed payload — the
    /// fp32 tensor is never stored.
    pub fn push_f32(&mut self, arena: &mut Arena, slot: SlotId,
                    v: &[f32]) -> Result<()> {
        let info = self.expect(slot)?;
        if let Some(g) = info.qgroup {
            let stored: usize = info.shape.iter().product();
            let groups = stored / (g + int8::GROUP_FOOTER_BYTES);
            ensure!(info.dtype == DType::I8 && groups * g == v.len(),
                    "slot {}.{} expects {} f32 elems ({} int8 groups \
                     of {g}), got {}",
                    info.module, info.kind.as_str(), groups * g, groups,
                    v.len());
            let mut data = arena.take_u8(stored);
            int8::quantize_into(v, g, &mut data);
            self.out.push(Tensor {
                shape: info.shape.clone(),
                dtype: DType::I8,
                data,
            });
            return Ok(());
        }
        ensure!(info.dtype == DType::F32
                    && info.shape.iter().product::<usize>() == v.len(),
                "slot {}.{} expects f32 shape {:?}, got {} elems",
                info.module, info.kind.as_str(), info.shape, v.len());
        self.out.push(arena.tensor_from_f32(&info.shape, v));
        Ok(())
    }

    /// Push a u8 residual, taking ownership of an arena byte buffer
    /// (packed code planes are encoded straight into their payload).
    pub fn push_u8(&mut self, slot: SlotId, data: Vec<u8>) -> Result<()> {
        let info = self.expect(slot)?;
        ensure!(info.dtype == DType::U8
                    && info.shape.iter().product::<usize>() == data.len(),
                "slot {}.{} expects u8 shape {:?}, got {} bytes",
                info.module, info.kind.as_str(), info.shape, data.len());
        self.out.push(Tensor {
            shape: info.shape.clone(),
            dtype: DType::U8,
            data,
        });
        Ok(())
    }

    /// Finish the pass; errors unless every slot was pushed.
    pub fn finish(self) -> Result<Vec<Tensor>> {
        ensure!(
            self.out.len() == self.schema.len(),
            "forward pushed {} of {} tape slots",
            self.out.len(),
            self.schema.len()
        );
        Ok(self.out)
    }
}

/// An f32 view of a popped/read residual: borrowed straight from the
/// tape for f32 slots, or an arena-backed dequantized copy for int8
/// (`_mesa`) slots. Call [`release`] when done so the owned buffer
/// returns to the arena free list (dropping it instead only costs the
/// steady-state zero-allocation property, which the arena tests pin).
///
/// [`release`]: ResF32::release
pub enum ResF32<'a> {
    /// The slot stores f32; this borrows the tape tensor directly.
    Borrowed(&'a [f32]),
    /// The slot stores int8 groups; this owns the dequantized copy.
    Owned(Vec<f32>),
}

impl ResF32<'_> {
    /// The f32 element view.
    pub fn as_f32(&self) -> &[f32] {
        match self {
            ResF32::Borrowed(s) => s,
            ResF32::Owned(v) => v,
        }
    }

    /// Hand an owned dequantized buffer back to the arena.
    pub fn release(self, arena: &mut Arena) {
        if let ResF32::Owned(v) = self {
            arena.put_f32(v);
        }
    }
}

/// Backward-pass tape over the residual list `fwd` produced: pops in
/// exact reverse push order (checked), with random-access [`get`] for
/// slots another layer owns (MS-norm sharing, attention's shared
/// linear input).
///
/// [`get`]: TapeReader::get
pub struct TapeReader<'a> {
    schema: &'a [SlotInfo],
    res: &'a [Tensor],
    top: usize,
}

impl<'a> TapeReader<'a> {
    /// A reader over `res`, which must match `schema` in arity.
    pub fn new(schema: &'a [SlotInfo],
               res: &'a [Tensor]) -> Result<TapeReader<'a>> {
        ensure!(
            res.len() == schema.len(),
            "residual list has {} tensors, tape schema has {}",
            res.len(),
            schema.len()
        );
        Ok(TapeReader { schema, res, top: res.len() })
    }

    /// Consume `slot`, which must be the next one in reverse order.
    pub fn pop(&mut self, slot: SlotId) -> Result<&'a Tensor> {
        ensure!(self.top > 0, "residual tape underflow");
        ensure!(
            slot.0 == self.top - 1,
            "tape pop out of order: slot #{} ({}) popped at top {}",
            slot.0,
            self.schema
                .get(slot.0)
                .map(|s| s.module.as_str())
                .unwrap_or("<foreign slot>"),
            self.top
        );
        let info = &self.schema[slot.0];
        self.top -= 1;
        let t = &self.res[slot.0];
        ensure!(t.dtype == info.dtype && t.shape == info.shape,
                "residual {}.{} does not match its slot: {:?} vs {:?}",
                info.module, info.kind.as_str(), t.shape, info.shape);
        Ok(t)
    }

    /// [`pop`](TapeReader::pop) as an f32 view: borrows the tensor for
    /// f32 slots, dequantizes int8 slots into an arena buffer.
    pub fn pop_f32(&mut self, arena: &mut Arena,
                   slot: SlotId) -> Result<ResF32<'a>> {
        let t = self.pop(slot)?;
        self.view_f32(arena, slot, t)
    }

    /// [`get`](TapeReader::get) as an f32 view. Each call on an int8
    /// slot dequantizes afresh (shared saves are read by every
    /// consumer), trading a little bwd time for the residual bytes —
    /// the Mesa tradeoff.
    pub fn get_f32(&self, arena: &mut Arena,
                   slot: SlotId) -> Result<ResF32<'a>> {
        let t = self.get(slot)?;
        self.view_f32(arena, slot, t)
    }

    fn view_f32(&self, arena: &mut Arena, slot: SlotId,
                t: &'a Tensor) -> Result<ResF32<'a>> {
        match self.schema[slot.0].qgroup {
            None => Ok(ResF32::Borrowed(t.as_f32())),
            Some(g) => {
                let groups =
                    t.data.len() / (g + int8::GROUP_FOOTER_BYTES);
                let mut v = arena.take_f32(groups * g);
                int8::dequantize_into(&t.data, g, &mut v);
                Ok(ResF32::Owned(v))
            }
        }
    }

    /// Read a not-yet-popped slot without consuming it (shared
    /// residuals: the owner pops it later, in its own reverse position).
    pub fn get(&self, slot: SlotId) -> Result<&'a Tensor> {
        ensure!(
            slot.0 < self.top,
            "tape get of popped or foreign slot #{} ({})",
            slot.0,
            self.schema
                .get(slot.0)
                .map(|s| s.module.as_str())
                .unwrap_or("<foreign slot>")
        );
        Ok(&self.res[slot.0])
    }

    /// Finish the pass; errors unless every slot was consumed.
    pub fn finish(self) -> Result<()> {
        ensure!(self.top == 0,
                "residual tape not fully consumed: {} slots left",
                self.top);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema2() -> Vec<SlotInfo> {
        let mut c = Composer::new();
        c.slot_f32("a", Kind::NormInput, &[2, 2]);
        c.slot("b", Kind::ActCodes, &[4], DType::U8, 2.0);
        c.finish()
    }

    #[test]
    fn push_pop_roundtrip_in_order() {
        let schema = schema2();
        let mut arena = Arena::new();
        let mut w = TapeWriter::new(&schema);
        w.push_f32(&mut arena, SlotId(0), &[1.0, 2.0, 3.0, 4.0]).unwrap();
        w.push_u8(SlotId(1), vec![9, 8, 7, 6]).unwrap();
        let res = w.finish().unwrap();
        let mut r = TapeReader::new(&schema, &res).unwrap();
        assert_eq!(r.get(SlotId(0)).unwrap().as_f32()[3], 4.0);
        assert_eq!(r.pop(SlotId(1)).unwrap().data, vec![9, 8, 7, 6]);
        assert_eq!(r.pop(SlotId(0)).unwrap().shape, vec![2, 2]);
        r.finish().unwrap();
    }

    #[test]
    fn out_of_order_push_is_rejected() {
        let schema = schema2();
        let mut w = TapeWriter::new(&schema);
        assert!(w.push_u8(SlotId(1), vec![0; 4]).is_err());
    }

    #[test]
    fn out_of_order_pop_and_stale_get_are_rejected() {
        let schema = schema2();
        let mut arena = Arena::new();
        let mut w = TapeWriter::new(&schema);
        w.push_f32(&mut arena, SlotId(0), &[0.0; 4]).unwrap();
        w.push_u8(SlotId(1), vec![0; 4]).unwrap();
        let res = w.finish().unwrap();
        let mut r = TapeReader::new(&schema, &res).unwrap();
        assert!(r.pop(SlotId(0)).is_err(), "must pop slot 1 first");
        r.pop(SlotId(1)).unwrap();
        assert!(r.get(SlotId(1)).is_err(), "slot 1 is consumed");
        r.pop(SlotId(0)).unwrap();
    }

    #[test]
    fn unfinished_passes_are_rejected() {
        let schema = schema2();
        let w = TapeWriter::new(&schema);
        assert!(w.finish().is_err());
        let res = vec![
            Tensor::from_f32(&[2, 2], &[0.0; 4]),
            Tensor::from_u8(&[4], &[0; 4]),
        ];
        let r = TapeReader::new(&schema, &res).unwrap();
        assert!(r.finish().is_err());
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let schema = schema2();
        let mut arena = Arena::new();
        let mut w = TapeWriter::new(&schema);
        assert!(w.push_f32(&mut arena, SlotId(0), &[0.0; 3]).is_err());
    }

    #[test]
    fn mesa_composer_quantizes_eligible_slots_transparently() {
        let mut c = Composer::with_mesa(true);
        let s0 = c.slot_f32("n", Kind::NormShared, &[2, 8]);
        let s1 = c.slot_f32("h", Kind::HeadInput, &[2, 8]);
        let schema = c.finish();
        // eligible kind: int8 group slot, stored [2, 8+4], 8+32/8 bits
        assert_eq!(schema[0].dtype, DType::I8);
        assert_eq!(schema[0].shape, vec![2, 12]);
        assert_eq!(schema[0].qgroup, Some(8));
        assert!((schema[0].bits_per_elem - 12.0).abs() < 1e-9);
        // ineligible kind stays f32 even under mesa
        assert_eq!(schema[1].dtype, DType::F32);
        // push f32 → stored int8 → pop_f32 roundtrips within scale/2
        let x: Vec<f32> =
            (0..16).map(|i| (i as f32 - 7.5) * 0.25).collect();
        let mut arena = Arena::new();
        let mut w = TapeWriter::new(&schema);
        w.push_f32(&mut arena, s0, &x).unwrap();
        w.push_f32(&mut arena, s1, &x).unwrap();
        let res = w.finish().unwrap();
        assert_eq!(res[0].dtype, DType::I8);
        assert_eq!(res[0].nbytes(), 2 * 12);
        let mut r = TapeReader::new(&schema, &res).unwrap();
        let shared = r.get_f32(&mut arena, s0).unwrap();
        assert!(matches!(shared, ResF32::Owned(_)));
        for (a, b) in shared.as_f32().iter().zip(&x) {
            assert!((a - b).abs() <= 7.5 * 0.25 / 127.0 * 0.5 + 1e-6);
        }
        shared.release(&mut arena);
        let head = r.pop_f32(&mut arena, s1).unwrap();
        assert!(matches!(head, ResF32::Borrowed(_)));
        assert_eq!(head.as_f32(), &x[..]);
        head.release(&mut arena);
        let xh = r.pop_f32(&mut arena, s0).unwrap();
        assert_eq!(xh.as_f32().len(), 16);
        xh.release(&mut arena);
        r.finish().unwrap();
    }
}
