//! Multi-head self-attention layer: q/k/v projections (each an embedded
//! [`LinOp`] with its own LoRA policy), optional rotary position
//! embedding (RoPE, adjacent-pair convention) applied to q/k, the
//! attention core with backward probability *recomputation* (only q/k/v
//! are saved — the FlashAttention residual policy the measured tape
//! assumes), and the output projection.
//!
//! The q/k/v linears read the same input, so when any of them needs its
//! input residual the layer stores it **once**: under a plain norm as a
//! joint `linear_input` slot owned here, under an MS norm as the norm's
//! shared x̂ (wired in as [`XSrc::Ext`](super::XSrc) at build time).
//! RoPE is applied *before* the q/k saves, so the backward recompute
//! uses the rotated tensors unchanged and only the q/k gradients need
//! the inverse rotation (RoPE is orthogonal: `dx = R(−θ)·dy`).

use anyhow::Result;

use super::super::kernels::{add_inplace, attn_bwd_into, attn_fwd_into,
                            rope_into, AttnDims};
use super::super::model::NetCfg;
use super::linear::{need_x, LinOp};
use super::tape::{Composer, Kind, SlotId, TapeReader, TapeWriter};
use super::{BwdCtx, FwdCtx, Layer, ParamReg};

/// Precomputed RoPE rotation tables (`[n_tokens, dh/2]` each).
struct Rope {
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl Rope {
    fn new(n: usize, dh: usize) -> Rope {
        let half = dh / 2;
        let mut cos = Vec::with_capacity(n * half);
        let mut sin = Vec::with_capacity(n * half);
        for pos in 0..n {
            for j in 0..half {
                let theta = pos as f64
                    * 10000f64.powf(-2.0 * j as f64 / dh as f64);
                cos.push(theta.cos() as f32);
                sin.push(theta.sin() as f32);
            }
        }
        Rope { cos, sin }
    }
}

/// Self-attention over a `[B·N, C]` running activation.
pub struct Attention {
    q: LinOp,
    k: LinOp,
    v: LinOp,
    proj: LinOp,
    q_slot: SlotId,
    k_slot: SlotId,
    v_slot: SlotId,
    /// Joint input save owned by this layer (plain norm + some of q/k/v
    /// needs its input); `None` when unneeded or shared with an MS norm.
    x_slot: Option<SlotId>,
    dims: AttnDims,
    causal: bool,
    rope: Option<Rope>,
}

impl Attention {
    /// Build the attention layer for module path `an` (e.g.
    /// `block0.attn`). `shared_x` is the MS norm's x̂ slot, when one
    /// exists.
    pub fn new(cfg: &NetCfg, reg: &mut ParamReg, comp: &mut Composer,
               an: &str, lead: &[usize],
               shared_x: Option<SlotId>) -> Attention {
        let c = cfg.dim;
        let needed =
            need_x(cfg, "q") || need_x(cfg, "k") || need_x(cfg, "v");
        let mut xshape = lead.to_vec();
        xshape.push(c);
        let (x_slot, x_ext) = match shared_x {
            Some(s) => (None, Some(s)),
            None if needed => {
                let s = comp.slot_f32(&format!("{an}.qkv"),
                                      Kind::LinearInput, &xshape);
                (Some(s), Some(s))
            }
            None => (None, None),
        };
        let q = LinOp::new(cfg, reg, comp, &format!("{an}.q"), "q", c, c,
                           lead, x_ext);
        let k = LinOp::new(cfg, reg, comp, &format!("{an}.k"), "k", c, c,
                           lead, x_ext);
        let v = LinOp::new(cfg, reg, comp, &format!("{an}.v"), "v", c, c,
                           lead, x_ext);
        let q_slot =
            comp.slot_f32(&format!("{an}.q"), Kind::AttnQkv, &xshape);
        let k_slot =
            comp.slot_f32(&format!("{an}.k"), Kind::AttnQkv, &xshape);
        let v_slot =
            comp.slot_f32(&format!("{an}.v"), Kind::AttnQkv, &xshape);
        let proj = LinOp::new(cfg, reg, comp, &format!("{an}.proj"),
                              "proj", c, c, lead, None);
        let dims = AttnDims {
            b: cfg.batch,
            n: cfg.n_tokens,
            h: cfg.n_heads,
            dh: c / cfg.n_heads,
        };
        Attention {
            q,
            k,
            v,
            proj,
            q_slot,
            k_slot,
            v_slot,
            x_slot,
            dims,
            causal: cfg.causal(),
            rope: if cfg.rope() {
                Some(Rope::new(cfg.n_tokens, dims.dh))
            } else {
                None
            },
        }
    }

    fn rows(&self) -> usize {
        self.dims.b * self.dims.n
    }
}

impl Layer for Attention {
    fn name(&self) -> &'static str {
        "Attention"
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        let rows = self.rows();
        let c = self.dims.h * self.dims.dh;
        if let Some(slot) = self.x_slot {
            tape.push_f32(ctx.arena, slot, &ctx.h)?;
        }
        let mut q =
            self.q.fwd(ctx.arena, ctx.params, tape, &ctx.h, rows)?;
        let mut k =
            self.k.fwd(ctx.arena, ctx.params, tape, &ctx.h, rows)?;
        let v = self.v.fwd(ctx.arena, ctx.params, tape, &ctx.h, rows)?;
        if let Some(r) = &self.rope {
            rope_into(&mut q, &r.cos, &r.sin, &self.dims, false);
            rope_into(&mut k, &r.cos, &r.sin, &self.dims, false);
        }
        tape.push_f32(ctx.arena, self.q_slot, &q)?;
        tape.push_f32(ctx.arena, self.k_slot, &k)?;
        tape.push_f32(ctx.arena, self.v_slot, &v)?;
        let mut o = ctx.arena.take_f32(rows * c);
        let mut hm = ctx.arena.take_f32(rows * c);
        attn_fwd_into(&mut o, &mut hm, &q, &k, &v, &self.dims,
                      self.causal);
        ctx.arena.put_f32(hm);
        ctx.arena.put_f32(q);
        ctx.arena.put_f32(k);
        ctx.arena.put_f32(v);
        let po = self.proj.fwd(ctx.arena, ctx.params, tape, &o, rows)?;
        ctx.arena.put_f32(o);
        ctx.set_h(po);
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        let rows = self.rows();
        let c = self.dims.h * self.dims.dh;
        let dy = std::mem::take(&mut ctx.dh);
        let do_ = self.proj.bwd(ctx, tape, &dy, rows)?;
        ctx.arena.put_f32(dy);
        let v = tape.pop(self.v_slot)?;
        let k = tape.pop(self.k_slot)?;
        let q = tape.pop(self.q_slot)?;
        let mut dq = ctx.arena.take_f32(rows * c);
        let mut dk = ctx.arena.take_f32(rows * c);
        let mut dv = ctx.arena.take_f32(rows * c);
        let mut scr = ctx.arena.take_f32(3 * rows * c);
        attn_bwd_into(&mut dq, &mut dk, &mut dv, &mut scr, &do_,
                      q.as_f32(), k.as_f32(), v.as_f32(), &self.dims,
                      self.causal);
        ctx.arena.put_f32(scr);
        ctx.arena.put_f32(do_);
        if let Some(r) = &self.rope {
            // gradient w.r.t. the pre-rotation q/k: rotate by −θ
            rope_into(&mut dq, &r.cos, &r.sin, &self.dims, true);
            rope_into(&mut dk, &r.cos, &r.sin, &self.dims, true);
        }
        // reverse push order: v's slots unwind before k's before q's
        let mut dxn = self.v.bwd(ctx, tape, &dv, rows)?;
        ctx.arena.put_f32(dv);
        let dk_in = self.k.bwd(ctx, tape, &dk, rows)?;
        ctx.arena.put_f32(dk);
        add_inplace(&mut dxn, &dk_in);
        ctx.arena.put_f32(dk_in);
        let dq_in = self.q.bwd(ctx, tape, &dq, rows)?;
        ctx.arena.put_f32(dq);
        add_inplace(&mut dxn, &dq_in);
        ctx.arena.put_f32(dq_in);
        if let Some(slot) = self.x_slot {
            tape.pop(slot)?;
        }
        ctx.dh = dxn;
        Ok(())
    }
}
