//! Gradient checkpointing as a *wrapper layer*: [`CkptBlock`] owns an
//! inner composition whose residual slots live on a **private tape**
//! (minted from the block's own [`Composer`]). On the model-level tape
//! the block contributes exactly one slot — its input — so the
//! measured activation memory between fwd and bwd drops to one
//! `[B,N,C]` tensor per wrapped block. The backward pass re-runs the
//! inner forward from the saved input to regenerate the private tape,
//! then runs the inner backward against it; recomputation uses the
//! same deterministic kernels, so gradients (and the bit-identical
//! across-thread-counts contract) are unchanged.

use anyhow::Result;

use super::tape::{Composer, Kind, SlotId, SlotInfo, TapeReader,
                  TapeWriter};
use super::{BwdCtx, FwdCtx, Layer};

/// Store-input/recompute wrapper around an inner layer stack.
pub struct CkptBlock {
    inner: Box<dyn Layer>,
    slot: SlotId,
    inner_schema: Vec<SlotInfo>,
}

impl CkptBlock {
    /// Wrap `inner` (built against its own composer, whose finished
    /// schema is `inner_schema`); mints the single `ckpt_input` slot on
    /// the model-level composer.
    pub fn new(comp: &mut Composer, module: &str, shape: &[usize],
               inner: Box<dyn Layer>,
               inner_schema: Vec<SlotInfo>) -> CkptBlock {
        let slot = comp.slot_f32(module, Kind::CkptInput, shape);
        CkptBlock { inner, slot, inner_schema }
    }

    /// The wrapped block's private residual schema (what bwd
    /// recomputes instead of storing).
    pub fn inner_schema(&self) -> &[SlotInfo] {
        &self.inner_schema
    }
}

impl Layer for CkptBlock {
    fn name(&self) -> &'static str {
        "CkptBlock"
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        tape.push_f32(ctx.arena, self.slot, &ctx.h)?;
        // run the inner forward against a throwaway private tape; its
        // residuals go straight back to the arena
        let mut w = TapeWriter::new(&self.inner_schema);
        let prof = ctx.profiler.take();
        let r = self.inner.fwd(ctx, &mut w);
        ctx.profiler = prof;
        r?;
        for t in w.finish()? {
            ctx.arena.recycle_tensor(t);
        }
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        let h0 = tape.pop(self.slot)?;
        // recompute the private tape from the saved block input
        let mut w = TapeWriter::new(&self.inner_schema);
        {
            let mut h = ctx.arena.take_f32(h0.elems());
            h.copy_from_slice(h0.as_f32());
            let mut fctx = FwdCtx {
                params: ctx.params,
                arena: &mut *ctx.arena,
                x: ctx.x,
                y: ctx.y,
                h,
                loss: 0.0,
                metric: 0.0,
                profiler: None,
            };
            self.inner.fwd(&mut fctx, &mut w)?;
            // the recomputed block output is not needed — only the tape
            fctx.set_h(Vec::new());
        }
        let scratch = w.finish()?;
        {
            let mut r = TapeReader::new(&self.inner_schema, &scratch)?;
            let prof = ctx.profiler.take();
            let res = self.inner.bwd(ctx, &mut r);
            ctx.profiler = prof;
            res?;
            r.finish()?;
        }
        for t in scratch {
            ctx.arena.recycle_tensor(t);
        }
        Ok(())
    }
}
