//! Linear projection with optional bias and optional LoRA adapter
//! (`y = x·Wᵀ [+ b] [+ (x·Aᵀ)·Bᵀ]`), plus the input-residual policy of
//! the paper: the input is saved only when some gradient needs it (base
//! weight trains, or a non-FA LoRA adapter needs `x` for
//! `dA = (dy·Bᵀ)ᵀ·x`), and it can be *shared* — with an MS norm's x̂ or
//! with sibling linears reading the same tensor — instead of stored
//! again (eq. 16–18).

use anyhow::Result;

use super::super::arena::Arena;
use super::super::gemm::gemm_packed_many;
use super::super::kernels::{
    add_bias, colsum_into, frozen_packed, matmul_nn_acc_into,
    matmul_nn_frozen_into, matmul_nn_into, matmul_nt_acc_into,
    matmul_nt_frozen_into, matmul_tn_into,
};
use super::super::model::NetCfg;
use super::tape::{Composer, Kind, SlotId, TapeReader, TapeWriter};
use super::{bwd_each, fwd_each, BwdCtx, BwdLane, FwdCtx, FwdLane, Layer,
            ParamReg};
use crate::runtime::params::Params;

/// Where a linear finds its input residual in the backward pass.
#[derive(Debug, Clone, Copy)]
pub enum XSrc {
    /// This linear saves (and pops) its own `linear_input` slot.
    Own(SlotId),
    /// The input lives in a slot another layer owns (an MS norm's
    /// shared x̂, or a joint save for sibling linears): read without
    /// consuming.
    Ext(SlotId),
    /// No gradient needs the input (frozen base, LoRA-FA).
    None,
}

/// The projection op: used standalone via the [`Linear`] layer and
/// embedded inside [`Attention`](super::Attention),
/// [`SwiGlu`](super::SwiGlu), and [`Head`](super::Head).
pub struct LinOp {
    /// Module path, e.g. `block0.mlp.fc1`.
    pub name: String,
    din: usize,
    dout: usize,
    w: usize,
    b: Option<usize>,
    la: Option<usize>,
    lb: Option<usize>,
    fa: bool,
    base_train: bool,
    rank: usize,
    x_src: XSrc,
    u_slot: Option<SlotId>,
}

/// Whether a linear must see its input in bwd under `cfg` — base weight
/// trains, or a non-FA LoRA adapter is attached.
pub fn need_x(cfg: &NetCfg, which: &str) -> bool {
    cfg.tuning_full() || (cfg.lora_on(which) && !cfg.lora_fa())
}

impl LinOp {
    /// Register parameters and mint slots for one linear.
    ///
    /// `x_ext`: a slot that already holds the input this linear reads
    /// (shared save) — when the input is needed and no external slot is
    /// given, the op mints its own `linear_input` slot, *before* the
    /// LoRA `u` slot, matching the canonical push order.
    #[allow(clippy::too_many_arguments)]
    pub fn new(cfg: &NetCfg, reg: &mut ParamReg, comp: &mut Composer,
               name: &str, which: &str, din: usize, dout: usize,
               lead: &[usize], x_ext: Option<SlotId>) -> LinOp {
        let full = cfg.tuning_full();
        let w = reg.add(format!("{name}.W"), vec![dout, din], full);
        let b = if cfg.use_bias() {
            Some(reg.add(format!("{name}.b"), vec![dout], full))
        } else {
            None
        };
        let lora = cfg.lora_on(which);
        let x_src = if need_x(cfg, which) {
            match x_ext {
                Some(s) => XSrc::Ext(s),
                None => {
                    let mut shape = lead.to_vec();
                    shape.push(din);
                    XSrc::Own(comp.slot_f32(name, Kind::LinearInput,
                                            &shape))
                }
            }
        } else {
            XSrc::None
        };
        let (la, lb, u_slot) = if lora {
            let r = cfg.lora_rank;
            let la = reg.add(format!("{name}.lora_a"), vec![r, din],
                             !cfg.lora_fa());
            let lb =
                reg.add(format!("{name}.lora_b"), vec![dout, r], true);
            let mut shape = lead.to_vec();
            shape.push(r);
            let u = comp.slot_f32(name, Kind::LoraU, &shape);
            (Some(la), Some(lb), Some(u))
        } else {
            (None, None, None)
        };
        LinOp {
            name: name.to_string(),
            din,
            dout,
            w,
            b,
            la,
            lb,
            fa: cfg.lora_fa(),
            base_train: full,
            rank: cfg.lora_rank,
            x_src,
            u_slot,
        }
    }

    /// A LoRA-free linear with explicit trainability and input source —
    /// the classifier/LM head, which is never adapted even under
    /// `lora_all`.
    pub fn new_plain(reg: &mut ParamReg, name: &str, din: usize,
                     dout: usize, trainable: bool, bias: bool,
                     x_src: XSrc) -> LinOp {
        let w = reg.add(format!("{name}.W"), vec![dout, din], trainable);
        let b = if bias {
            Some(reg.add(format!("{name}.b"), vec![dout], trainable))
        } else {
            None
        };
        LinOp {
            name: name.to_string(),
            din,
            dout,
            w,
            b,
            la: None,
            lb: None,
            fa: false,
            base_train: trainable,
            rank: 0,
            x_src,
            u_slot: None,
        }
    }

    /// Output width.
    pub fn dout(&self) -> usize {
        self.dout
    }

    /// `y = x·Wᵀ [+ b] [+ uBᵀ]`; pushes the own input slot (if any) and
    /// the LoRA `u` slot.
    pub fn fwd(&self, arena: &mut Arena, params: Params<'_>,
               tape: &mut TapeWriter, x: &[f32],
               rows: usize) -> Result<Vec<f32>> {
        if let XSrc::Own(slot) = self.x_src {
            tape.push_f32(arena, slot, x)?;
        }
        let mut y = arena.take_f32(rows * self.dout);
        matmul_nt_frozen_into(&mut y, x, params, self.w, rows, self.din,
                              self.dout);
        if let Some(bi) = self.b {
            add_bias(&mut y, params[bi].as_f32());
        }
        if let (Some(lai), Some(lbi), Some(us)) =
            (self.la, self.lb, self.u_slot)
        {
            let r = self.rank;
            let mut u = arena.take_f32(rows * r);
            matmul_nt_frozen_into(&mut u, x, params, lai, rows,
                                  self.din, r);
            tape.push_f32(arena, us, &u)?;
            matmul_nt_acc_into(&mut y, &u, params[lbi].as_f32(), rows, r,
                               self.dout);
            arena.put_f32(u);
        }
        Ok(y)
    }

    /// Backward: pops the LoRA `u` and own-input slots (in reverse push
    /// order), accumulates `dW`/`db`/`dA`/`dB`, returns `dx`.
    ///
    /// The input residual is read through the tape's f32 view: an MS
    /// norm's shared x̂ may be a quantized int8 slot under `_mesa`, in
    /// which case the gradient products run over the dequantized copy
    /// (the Mesa approximation).
    pub fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader,
               dy: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.bwd_with(ctx, tape, dy, rows, None)
    }

    /// [`LinOp::bwd`] with an optionally precomputed main product
    /// `dx = dy·W`. The fused cross-session path batches that GEMM
    /// across lanes before the per-lane chain runs; `dy·W` reads only
    /// `dy` and the frozen `W`, independent of everything the chain
    /// computes, so hoisting it is bit-invisible per session.
    fn bwd_with(&self, ctx: &mut BwdCtx, tape: &mut TapeReader,
                dy: &[f32], rows: usize,
                dx_pre: Option<Vec<f32>>) -> Result<Vec<f32>> {
        let u = match self.u_slot {
            Some(s) => Some(tape.pop(s)?),
            None => None,
        };
        let x = match self.x_src {
            XSrc::Own(s) => Some(tape.pop_f32(ctx.arena, s)?),
            XSrc::Ext(s) => Some(tape.get_f32(ctx.arena, s)?),
            XSrc::None => None,
        };
        if self.base_train {
            let xx = x
                .as_ref()
                .expect("linear input residual missing")
                .as_f32();
            let mut dw = ctx.arena.take_f32(self.dout * self.din);
            matmul_tn_into(&mut dw, dy, xx, self.dout, rows, self.din);
            ctx.acc(self.w, dw);
            if let Some(bi) = self.b {
                let mut db = ctx.arena.take_f32(self.dout);
                colsum_into(&mut db, dy, rows, self.dout);
                ctx.acc(bi, db);
            }
        }
        let mut dx = match dx_pre {
            Some(dx) => dx,
            None => {
                let mut dx = ctx.arena.take_f32(rows * self.din);
                matmul_nn_frozen_into(&mut dx, dy, ctx.params, self.w,
                                      rows, self.dout, self.din);
                dx
            }
        };
        if let (Some(lai), Some(lbi)) = (self.la, self.lb) {
            let r = self.rank;
            let uu = u.expect("lora_u residual missing").as_f32();
            let mut du = ctx.arena.take_f32(rows * r);
            matmul_nn_into(&mut du, dy, ctx.params[lbi].as_f32(), rows,
                           self.dout, r);
            let mut dlb = ctx.arena.take_f32(self.dout * r);
            matmul_tn_into(&mut dlb, dy, uu, self.dout, rows, r);
            ctx.acc(lbi, dlb);
            if !self.fa {
                let xx = x
                    .as_ref()
                    .expect("linear input residual missing (lora)")
                    .as_f32();
                let mut dla = ctx.arena.take_f32(r * self.din);
                matmul_tn_into(&mut dla, &du, xx, r, rows, self.din);
                ctx.acc(lai, dla);
            }
            matmul_nn_acc_into(&mut dx, &du, ctx.params[lai].as_f32(),
                               rows, r, self.din);
            ctx.arena.put_f32(du);
        }
        if let Some(x) = x {
            x.release(ctx.arena);
        }
        Ok(dx)
    }
}

/// Standalone linear layer over the running activation.
pub struct Linear {
    op: LinOp,
    rows: usize,
}

impl Linear {
    /// Build a linear layer (`lead` = leading activation dims, e.g.
    /// `[batch, n_tokens]`).
    #[allow(clippy::too_many_arguments)]
    pub fn new(cfg: &NetCfg, reg: &mut ParamReg, comp: &mut Composer,
               name: &str, which: &str, din: usize, dout: usize,
               lead: &[usize], x_ext: Option<SlotId>) -> Linear {
        Linear {
            op: LinOp::new(cfg, reg, comp, name, which, din, dout, lead,
                           x_ext),
            rows: lead.iter().product(),
        }
    }
}

impl Layer for Linear {
    fn name(&self) -> &'static str {
        "Linear"
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        let y =
            self.op.fwd(ctx.arena, ctx.params, tape, &ctx.h, self.rows)?;
        ctx.set_h(y);
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        let dy = std::mem::take(&mut ctx.dh);
        let dx = self.op.bwd(ctx, tape, &dy, self.rows)?;
        ctx.arena.put_f32(dy);
        ctx.dh = dx;
        Ok(())
    }

    /// Fused cross-tenant forward: when every lane reads the same
    /// frozen `W` through one shared [`PanelCache`], the main product
    /// `y = x·Wᵀ` runs as a single [`gemm_packed_many`] sweep — each
    /// KC block of the packed panel visits all N activation blocks
    /// before the k cursor advances. Bias, LoRA, and tape pushes stay
    /// per-lane, in the serial op order, so each lane's step remains
    /// bit-identical to its serial twin. Falls back to the per-lane
    /// walk whenever `W` trains or the lanes do not share a base.
    ///
    /// [`PanelCache`]: crate::runtime::params::PanelCache
    fn fwd_many(&self, arena: &mut Arena,
                lanes: &mut [FwdLane<'_>]) -> Result<()> {
        let fusable = lanes.len() > 1 && {
            let mut caches =
                lanes.iter().map(|l| l.params.frozen_cache(self.op.w));
            match caches.next().flatten() {
                Some((c0, _)) => caches.all(
                    |c| matches!(c, Some((c, _)) if std::ptr::eq(c, c0)),
                ),
                None => false,
            }
        };
        if !fusable {
            return fwd_each(self, arena, lanes);
        }
        let pb = frozen_packed(lanes[0].params, self.op.w, self.op.din,
                               self.op.dout, true)
            .expect("frozen_cache verified for every lane");
        let rows = self.rows;
        // per-lane prologue: input save + output buffer
        let mut ys: Vec<Vec<f32>> = Vec::with_capacity(lanes.len());
        for lane in lanes.iter_mut() {
            if let XSrc::Own(slot) = self.op.x_src {
                lane.tape.push_f32(arena, slot, &lane.h)?;
            }
            ys.push(arena.take_f32(rows * self.op.dout));
        }
        // one packed sweep across every lane's activation block
        {
            let mut crefs: Vec<&mut [f32]> =
                ys.iter_mut().map(|y| y.as_mut_slice()).collect();
            let xrefs: Vec<&[f32]> =
                lanes.iter().map(|l| l.h.as_slice()).collect();
            gemm_packed_many(&mut crefs, &xrefs, &pb, rows, false,
                             false);
        }
        // per-lane epilogue: bias, LoRA, activation handoff
        for (lane, mut y) in lanes.iter_mut().zip(ys) {
            if let Some(bi) = self.op.b {
                add_bias(&mut y, lane.params[bi].as_f32());
            }
            if let (Some(lai), Some(lbi), Some(us)) =
                (self.op.la, self.op.lb, self.op.u_slot)
            {
                let r = self.op.rank;
                let mut u = arena.take_f32(rows * r);
                matmul_nt_frozen_into(&mut u, &lane.h, lane.params, lai,
                                      rows, self.op.din, r);
                lane.tape.push_f32(arena, us, &u)?;
                matmul_nt_acc_into(&mut y, &u,
                                   lane.params[lbi].as_f32(), rows, r,
                                   self.op.dout);
                arena.put_f32(u);
            }
            let old = std::mem::replace(&mut lane.h, y);
            arena.put_f32(old);
        }
        Ok(())
    }

    /// Fused cross-tenant backward: the main product `dx = dy·W`
    /// (frozen `W`, untransposed layout) is batched across lanes, then
    /// the per-lane chain (tape pops, LoRA gradients) runs with the
    /// precomputed product — `dy·W` reads nothing the chain writes, so
    /// hoisting it is bit-invisible per session.
    fn bwd_many(&self, arena: &mut Arena,
                lanes: &mut [BwdLane<'_>]) -> Result<()> {
        let fusable = lanes.len() > 1 && {
            let mut caches =
                lanes.iter().map(|l| l.params.frozen_cache(self.op.w));
            match caches.next().flatten() {
                Some((c0, _)) => caches.all(
                    |c| matches!(c, Some((c, _)) if std::ptr::eq(c, c0)),
                ),
                None => false,
            }
        };
        if !fusable {
            return bwd_each(self, arena, lanes);
        }
        let pb = frozen_packed(lanes[0].params, self.op.w, self.op.dout,
                               self.op.din, false)
            .expect("frozen_cache verified for every lane");
        let rows = self.rows;
        let mut dxs: Vec<Vec<f32>> = (0..lanes.len())
            .map(|_| arena.take_f32(rows * self.op.din))
            .collect();
        {
            let mut crefs: Vec<&mut [f32]> =
                dxs.iter_mut().map(|d| d.as_mut_slice()).collect();
            let dyrefs: Vec<&[f32]> =
                lanes.iter().map(|l| l.dh.as_slice()).collect();
            gemm_packed_many(&mut crefs, &dyrefs, &pb, rows, false,
                             false);
        }
        for (lane, dx) in lanes.iter_mut().zip(dxs) {
            let dy = std::mem::take(&mut lane.dh);
            let dx = {
                let mut ctx = BwdCtx {
                    params: lane.params,
                    infos: lane.infos,
                    arena: &mut *arena,
                    x: lane.x,
                    y: lane.y,
                    dh: Vec::new(),
                    grads: lane.grads.as_mut_slice(),
                    profiler: None,
                };
                let dx = self.op.bwd_with(&mut ctx, &mut lane.tape,
                                          &dy, rows, Some(dx))?;
                ctx.arena.put_f32(dy);
                dx
            };
            lane.dh = dx;
        }
        Ok(())
    }
}
