//! Cache-blocked f32 GEMM for the native backend.
//!
//! BLIS-style structure: the k dimension is split into `KC` blocks, B is
//! packed **once per call** into `NR`-column micro-panels (reused across
//! every row block and every worker — the packing cost is `O(k·n)`
//! against `O(m·k·n)` compute), and each worker packs its own `MC`-row ×
//! `KC` slice of A into `MR`-row micro-panels. The inner microkernel
//! holds an `MR × NR` f32 accumulator tile in registers and walks the
//! packed panels contiguously — plain unrolled array code that the
//! autovectorizer turns into SIMD FMAs (`MR=4, NR=8` keeps the tile
//! within the 16 baseline x86-64 vector registers without mandating
//! AVX).
//!
//! Edge tiles are handled by zero-padding the packed panels to full
//! `MR`/`NR` width, so the microkernel has a single shape; only the
//! writeback masks to the valid `C` region.
//!
//! ## Determinism
//!
//! For every output element the k-axis is reduced strictly in ascending
//! order — sequentially inside a `KC` block and block-by-block across
//! them — by exactly one worker. Chunk partition and worker count are
//! therefore invisible in the result bits (the pool's determinism
//! contract). Relative to a naive `Σ_t a[i,t]·b[t,j]` loop the result is
//! bit-identical for `k ≤ KC`; for larger `k` the per-block register
//! tile introduces one reassociation point per `KC` rows (documented in
//! DESIGN.md — all gradcheck tolerances are unaffected).
//!
//! Pack buffers are thread-local and grow-only, so steady-state GEMM
//! dispatch performs no heap allocation.

use std::cell::RefCell;

use super::pool::parallel_rows;

/// Microkernel rows (register-tile height).
pub const MR: usize = 4;
/// Microkernel columns (register-tile width).
pub const NR: usize = 8;
/// k-axis cache block (shared by A and B panels).
pub const KC: usize = 256;
/// Row cache block packed per worker (`MC × KC` f32 ≈ 64 KiB, L2-sized).
pub const MC: usize = 64;

thread_local! {
    // Packed A (per worker: its own MC×KC slice).
    static PACK_A: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    // Packed B (caller thread: the whole k×n operand, shared read-only
    // with the workers for the duration of the dispatch).
    static PACK_B: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Same work-per-row heuristic the elementwise kernels use: target
/// ≳32 Ki flops per parallel chunk.
fn grain(work_per_row: usize) -> usize {
    (1 << 15) / work_per_row.max(1) + 1
}

/// `C[m,n] {=, +=} op_a(A) · op_b(B)` where `op_a(A)` is `A[m,k]`
/// (`a_trans = false`) or `A[k,m]ᵀ` (`a_trans = true`), and `op_b(B)` is
/// `B[k,n]` (`b_trans = false`) or `B[n,k]ᵀ` (`b_trans = true`).
/// `acc = false` overwrites `C`, `acc = true` accumulates into it.
pub fn gemm_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize,
                 n: usize, a_trans: bool, b_trans: bool, acc: bool) {
    assert_eq!(a.len(), m * k, "gemm: bad A length");
    assert_eq!(b.len(), k * n, "gemm: bad B length");
    assert_eq!(c.len(), m * n, "gemm: bad C length");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !acc {
            c.fill(0.0);
        }
        return;
    }
    let n_panels = n.div_ceil(NR);
    let pcols = n_panels * NR;
    PACK_B.with(|cell| {
        let mut pbuf = cell.borrow_mut();
        let need = k * pcols;
        if pbuf.len() < need {
            pbuf.resize(need, 0.0);
        }
        let pb = &mut pbuf[..need];
        let mut kz = 0;
        while kz < k {
            let kcl = KC.min(k - kz);
            pack_b(&mut pb[kz * pcols..(kz + kcl) * pcols], b, k, n, kz,
                   kcl, b_trans);
            kz += KC;
        }
        let pb: &[f32] = pb;
        parallel_rows(c, n, grain(2 * k * n), |i0, chunk| {
            gemm_rows(chunk, i0, a, pb, m, k, n, a_trans, acc);
        });
    });
}

/// A B operand packed once into the same NR-column micro-panel layout
/// `gemm_into` builds per call, reusable across calls (and across
/// sessions) as long as the underlying weights do not change. The
/// panels carry their logical `[k, n]` shape so a handle can be
/// validity-checked against the operand it claims to represent.
///
/// Bit-identity: [`gemm_packed_into`] hands these panels to the *same*
/// `gemm_rows` worker loop `gemm_into` uses, so reusing a pack is
/// invisible in the result bits — only the `O(k·n)` packing work is
/// skipped.
pub struct PackedB {
    panels: Vec<f32>,
    k: usize,
    n: usize,
    pcols: usize,
}

impl PackedB {
    /// Logical shape `(k, n)` of the packed operand.
    pub fn shape(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// Resident bytes of the packed panels (n is padded up to a
    /// multiple of NR, so this is slightly above `4·k·n`).
    pub fn nbytes(&self) -> u64 {
        (self.panels.len() * std::mem::size_of::<f32>()) as u64
    }
}

/// Pack `op_b(B)` (`B[k,n]`, or `B[n,k]ᵀ` when `b_trans`) once into an
/// owned panel buffer. The packing loop is byte-for-byte the one
/// `gemm_into` runs per call.
pub fn pack_b_once(b: &[f32], k: usize, n: usize,
                   b_trans: bool) -> PackedB {
    assert_eq!(b.len(), k * n, "pack_b_once: bad B length");
    let pcols = n.div_ceil(NR) * NR;
    let mut panels = vec![0f32; k * pcols];
    let mut kz = 0;
    while kz < k {
        let kcl = KC.min(k - kz);
        pack_b(&mut panels[kz * pcols..(kz + kcl) * pcols], b, k, n, kz,
               kcl, b_trans);
        kz += KC;
    }
    PackedB { panels, k, n, pcols }
}

/// [`gemm_into`] against an already-packed B: identical worker loop,
/// identical k-order, identical result bits — minus the per-call
/// `O(k·n)` packing.
pub fn gemm_packed_into(c: &mut [f32], a: &[f32], pb: &PackedB,
                        m: usize, a_trans: bool, acc: bool) {
    let (k, n) = (pb.k, pb.n);
    assert_eq!(a.len(), m * k, "gemm_packed: bad A length");
    assert_eq!(c.len(), m * n, "gemm_packed: bad C length");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !acc {
            c.fill(0.0);
        }
        return;
    }
    let panels: &[f32] = &pb.panels;
    parallel_rows(c, n, grain(2 * k * n), |i0, chunk| {
        gemm_rows(chunk, i0, a, panels, m, k, n, a_trans, acc);
    });
}

/// N independent GEMMs over **one** packed B: for each KC block the
/// panel is swept through every session's activation block before the
/// k cursor advances, so the frozen operand is streamed through cache
/// once per block instead of once per session.
///
/// Per session the arithmetic is exactly [`gemm_packed_into`]'s: the
/// monolithic path also accumulates `C += tile(kz)` block-by-block in
/// ascending `kz` order (the microkernel writes its local tile back
/// after every KC block), so dispatching the blocks one at a time
/// per session leaves every session's result bit-identical to its
/// serial run.
pub fn gemm_packed_many(cs: &mut [&mut [f32]], activations: &[&[f32]],
                        pb: &PackedB, m: usize, a_trans: bool,
                        acc: bool) {
    assert_eq!(cs.len(), activations.len(),
               "gemm_packed_many: C/A arity mismatch");
    let (k, n) = (pb.k, pb.n);
    for (c, a) in cs.iter().zip(activations) {
        assert_eq!(a.len(), m * k, "gemm_packed_many: bad A length");
        assert_eq!(c.len(), m * n, "gemm_packed_many: bad C length");
    }
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !acc {
            for c in cs.iter_mut() {
                c.fill(0.0);
            }
        }
        return;
    }
    let panels: &[f32] = &pb.panels;
    let mut kz = 0;
    while kz < k {
        let kcl = KC.min(k - kz);
        for (c, a) in cs.iter_mut().zip(activations) {
            parallel_rows(c, n, grain(2 * kcl * n), |i0, chunk| {
                gemm_rows_kblock(chunk, i0, a, panels, m, k, n, kz, kcl,
                                 a_trans, acc);
            });
        }
        kz += KC;
    }
}

/// Pack the `[kz, kz+kcl)` k-rows of B into NR-column micro-panels:
/// panel `jp` holds `b(kz+t, jp·NR + j)` at `[t·NR + j]`, zero-padded in
/// `j` past the matrix edge.
fn pack_b(dst: &mut [f32], b: &[f32], k: usize, n: usize, kz: usize,
          kcl: usize, b_trans: bool) {
    let n_panels = n.div_ceil(NR);
    for jp in 0..n_panels {
        let j0 = jp * NR;
        let nr_eff = NR.min(n - j0);
        let panel = &mut dst[jp * kcl * NR..(jp + 1) * kcl * NR];
        if !b_trans {
            // B row-major [k, n]: contiguous row segments
            for t in 0..kcl {
                let src = &b[(kz + t) * n + j0..(kz + t) * n + j0 + nr_eff];
                let drow = &mut panel[t * NR..(t + 1) * NR];
                drow[..nr_eff].copy_from_slice(src);
                for v in &mut drow[nr_eff..] {
                    *v = 0.0;
                }
            }
        } else {
            // B is [n, k]: b(t, j) = B[j·k + t] — transposing gather
            for j in 0..NR {
                if j < nr_eff {
                    let src = &b[(j0 + j) * k..(j0 + j + 1) * k];
                    for t in 0..kcl {
                        panel[t * NR + j] = src[kz + t];
                    }
                } else {
                    for t in 0..kcl {
                        panel[t * NR + j] = 0.0;
                    }
                }
            }
        }
    }
}

/// Pack `mcl` rows of A starting at global row `i0` (k-range
/// `[kz, kz+kcl)`) into MR-row micro-panels: panel `ip` holds
/// `a(i0 + ip·MR + i, kz + t)` at `[t·MR + i]`, zero-padded in `i`.
fn pack_a(dst: &mut [f32], a: &[f32], m: usize, k: usize, i0: usize,
          mcl: usize, kz: usize, kcl: usize, a_trans: bool) {
    let mpanels = mcl.div_ceil(MR);
    for ip in 0..mpanels {
        let r0 = ip * MR;
        let mr_eff = MR.min(mcl - r0);
        let panel = &mut dst[ip * kcl * MR..(ip + 1) * kcl * MR];
        if !a_trans {
            // A row-major [m, k]: a(i, t) = A[i·k + t]
            for i in 0..MR {
                if i < mr_eff {
                    let src = &a[(i0 + r0 + i) * k..(i0 + r0 + i + 1) * k];
                    for t in 0..kcl {
                        panel[t * MR + i] = src[kz + t];
                    }
                } else {
                    for t in 0..kcl {
                        panel[t * MR + i] = 0.0;
                    }
                }
            }
        } else {
            // A is [k, m]: a(i, t) = A[t·m + i] — contiguous row pieces
            for t in 0..kcl {
                let src = &a[(kz + t) * m + i0 + r0..];
                let drow = &mut panel[t * MR..(t + 1) * MR];
                for (d, &s) in drow[..mr_eff].iter_mut().zip(src) {
                    *d = s;
                }
                for v in &mut drow[mr_eff..] {
                    *v = 0.0;
                }
            }
        }
    }
}

/// One worker's row chunk: `chunk` covers global C rows
/// `[i0, i0 + chunk.len()/n)`.
fn gemm_rows(chunk: &mut [f32], i0: usize, a: &[f32], pb: &[f32],
             m: usize, k: usize, n: usize, a_trans: bool, acc: bool) {
    let rows = chunk.len() / n;
    if !acc {
        chunk.fill(0.0);
    }
    let n_panels = n.div_ceil(NR);
    let pcols = n_panels * NR;
    PACK_A.with(|cell| {
        let mut pa = cell.borrow_mut();
        if pa.len() < MC * KC {
            pa.resize(MC * KC, 0.0);
        }
        let mut kz = 0;
        while kz < k {
            let kcl = KC.min(k - kz);
            let bblock = &pb[kz * pcols..(kz + kcl) * pcols];
            let mut ib = 0;
            while ib < rows {
                let mcl = MC.min(rows - ib);
                let mpanels = mcl.div_ceil(MR);
                pack_a(&mut pa[..mpanels * kcl * MR], a, m, k, i0 + ib,
                       mcl, kz, kcl, a_trans);
                for jp in 0..n_panels {
                    let bpanel =
                        &bblock[jp * kcl * NR..(jp + 1) * kcl * NR];
                    let j0 = jp * NR;
                    let nr_eff = NR.min(n - j0);
                    for ip in 0..mpanels {
                        let apanel =
                            &pa[ip * kcl * MR..(ip + 1) * kcl * MR];
                        let mr_eff = MR.min(mcl - ip * MR);
                        let coff = (ib + ip * MR) * n + j0;
                        micro(apanel, bpanel, &mut chunk[coff..], n,
                              mr_eff, nr_eff);
                    }
                }
                ib += MC;
            }
            kz += KC;
        }
    });
}

/// One worker's row chunk restricted to a single KC block `[kz,
/// kz+kcl)` — the body of `gemm_rows`' outer k loop, extracted so
/// [`gemm_packed_many`] can interleave sessions between blocks. The
/// chunk is zeroed only on the first block (`kz == 0`, `!acc`), so
/// successive blocks accumulate exactly as the monolithic loop does.
#[allow(clippy::too_many_arguments)]
fn gemm_rows_kblock(chunk: &mut [f32], i0: usize, a: &[f32], pb: &[f32],
                    m: usize, k: usize, n: usize, kz: usize, kcl: usize,
                    a_trans: bool, acc: bool) {
    let rows = chunk.len() / n;
    if kz == 0 && !acc {
        chunk.fill(0.0);
    }
    let n_panels = n.div_ceil(NR);
    let pcols = n_panels * NR;
    PACK_A.with(|cell| {
        let mut pa = cell.borrow_mut();
        if pa.len() < MC * KC {
            pa.resize(MC * KC, 0.0);
        }
        let bblock = &pb[kz * pcols..(kz + kcl) * pcols];
        let mut ib = 0;
        while ib < rows {
            let mcl = MC.min(rows - ib);
            let mpanels = mcl.div_ceil(MR);
            pack_a(&mut pa[..mpanels * kcl * MR], a, m, k, i0 + ib, mcl,
                   kz, kcl, a_trans);
            for jp in 0..n_panels {
                let bpanel = &bblock[jp * kcl * NR..(jp + 1) * kcl * NR];
                let j0 = jp * NR;
                let nr_eff = NR.min(n - j0);
                for ip in 0..mpanels {
                    let apanel = &pa[ip * kcl * MR..(ip + 1) * kcl * MR];
                    let mr_eff = MR.min(mcl - ip * MR);
                    let coff = (ib + ip * MR) * n + j0;
                    micro(apanel, bpanel, &mut chunk[coff..], n, mr_eff,
                          nr_eff);
                }
            }
            ib += MC;
        }
    });
}

/// The register-tiled microkernel: `C[mr_eff, nr_eff] += Ap · Bp` over
/// one KC block, with the full `MR × NR` accumulator tile kept local so
/// the inner loop is a broadcast-multiply-accumulate the compiler can
/// vectorize. `ldc` is the C row stride.
#[inline]
fn micro(apanel: &[f32], bpanel: &[f32], c: &mut [f32], ldc: usize,
         mr_eff: usize, nr_eff: usize) {
    let mut acc = [[0f32; NR]; MR];
    for (ar, br) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)) {
        for i in 0..MR {
            let ai = ar[i];
            let row = &mut acc[i];
            for (rv, &bv) in row.iter_mut().zip(br) {
                *rv += ai * bv;
            }
        }
    }
    for (i, arow) in acc.iter().enumerate().take(mr_eff) {
        let crow = &mut c[i * ldc..i * ldc + nr_eff];
        for (cv, &av) in crow.iter_mut().zip(&arow[..nr_eff]) {
            *cv += av;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
             at: bool, bt: bool) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for t in 0..k {
                    let av = if at { a[t * m + i] } else { a[i * k + t] };
                    let bv = if bt { b[j * k + t] } else { b[t * n + j] };
                    s += (av * bv) as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    fn check(m: usize, k: usize, n: usize, at: bool, bt: bool,
             seed: u64) {
        let mut rng = Rng::new(seed);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let want = naive(&a, &b, m, k, n, at, bt);
        let mut c = vec![0f32; m * n];
        gemm_into(&mut c, &a, &b, m, k, n, at, bt, false);
        for (i, (x, y)) in c.iter().zip(&want).enumerate() {
            assert!(
                (x - y).abs() < 1e-3 * y.abs().max(1.0),
                "m={m} k={k} n={n} at={at} bt={bt} i={i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn matches_naive_across_shapes_and_layouts() {
        // edge-heavy shapes: non-multiples of MR/NR/KC/MC, tiny dims
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 8, 8),
            (5, 9, 17),
            (65, 37, 23),
            (70, 300, 33), // k > KC: two k-blocks
            (130, 16, 9),  // rows > MC
        ] {
            for (at, bt) in
                [(false, false), (false, true), (true, false)]
            {
                check(m, k, n, at, bt, (m * 31 + k * 7 + n) as u64);
            }
        }
    }

    #[test]
    fn acc_accumulates_instead_of_overwriting() {
        let mut rng = Rng::new(9);
        let (m, k, n) = (6, 10, 11);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let want = naive(&a, &b, m, k, n, false, false);
        let mut c = vec![1.5f32; m * n];
        gemm_into(&mut c, &a, &b, m, k, n, false, false, true);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - (y + 1.5)).abs() < 1e-3 * y.abs().max(1.0));
        }
    }

    #[test]
    fn k_zero_zeroes_or_preserves() {
        let a: [f32; 0] = [];
        let b: [f32; 0] = [];
        let mut c = vec![2.0f32; 4];
        gemm_into(&mut c, &a, &b, 2, 0, 2, false, false, true);
        assert_eq!(c, vec![2.0; 4]);
        gemm_into(&mut c, &a, &b, 2, 0, 2, false, false, false);
        assert_eq!(c, vec![0.0; 4]);
    }

    #[test]
    fn packed_reuse_is_bit_identical_to_fresh_pack() {
        let mut rng = Rng::new(21);
        // k > KC to cross a block boundary; ragged m/n
        let (m, k, n) = (37, 300, 29);
        for bt in [false, true] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let mut want = vec![0f32; m * n];
            gemm_into(&mut want, &a, &b, m, k, n, false, bt, false);
            let pb = pack_b_once(&b, k, n, bt);
            assert_eq!(pb.shape(), (k, n));
            assert!(pb.nbytes() >= (4 * k * n) as u64);
            // reuse the pack twice — both results bit-equal to fresh
            for _ in 0..2 {
                let mut c = vec![0f32; m * n];
                gemm_packed_into(&mut c, &a, &pb, m, false, false);
                assert_eq!(c, want, "bt={bt}");
            }
            // and the accumulate path
            let mut c = vec![1.5f32; m * n];
            let mut cref = vec![1.5f32; m * n];
            gemm_packed_into(&mut c, &a, &pb, m, false, true);
            gemm_into(&mut cref, &a, &b, m, k, n, false, bt, true);
            assert_eq!(c, cref, "acc bt={bt}");
        }
    }

    #[test]
    fn packed_many_matches_per_session_serial_bitwise() {
        use crate::runtime::native::pool::with_threads;
        let mut rng = Rng::new(33);
        let (m, k, n) = (18, 520, 23); // three KC blocks
        let b = randv(&mut rng, k * n);
        let activations: Vec<Vec<f32>> =
            (0..4).map(|_| randv(&mut rng, m * k)).collect();
        let pb = pack_b_once(&b, k, n, false);
        // serial twins, one gemm per session
        let want: Vec<Vec<f32>> = activations
            .iter()
            .map(|a| {
                let mut c = vec![0f32; m * n];
                gemm_into(&mut c, a, &b, m, k, n, false, false, false);
                c
            })
            .collect();
        for nt in [1usize, 4] {
            let mut cs: Vec<Vec<f32>> =
                (0..4).map(|_| vec![0f32; m * n]).collect();
            with_threads(nt, || {
                let mut crefs: Vec<&mut [f32]> =
                    cs.iter_mut().map(|c| c.as_mut_slice()).collect();
                let arefs: Vec<&[f32]> =
                    activations.iter().map(|a| a.as_slice()).collect();
                gemm_packed_many(&mut crefs, &arefs, &pb, m, false,
                                 false);
            });
            for (s, (c, w)) in cs.iter().zip(&want).enumerate() {
                assert_eq!(c, w, "session {s} nt={nt}");
            }
        }
    }

    #[test]
    fn packed_many_k_zero_and_acc_edges() {
        let b: [f32; 0] = [];
        let pb = pack_b_once(&b, 0, 2, false);
        let a: [f32; 0] = [];
        let mut c0 = vec![2.0f32; 4];
        let mut c1 = vec![3.0f32; 4];
        {
            let mut cs: Vec<&mut [f32]> =
                vec![c0.as_mut_slice(), c1.as_mut_slice()];
            gemm_packed_many(&mut cs, &[&a, &a], &pb, 2, false, true);
        }
        assert_eq!(c0, vec![2.0; 4]);
        {
            let mut cs: Vec<&mut [f32]> =
                vec![c0.as_mut_slice(), c1.as_mut_slice()];
            gemm_packed_many(&mut cs, &[&a, &a], &pb, 2, false, false);
        }
        assert_eq!(c0, vec![0.0; 4]);
        assert_eq!(c1, vec![0.0; 4]);
    }

    #[test]
    fn thread_partition_is_bit_invisible() {
        use crate::runtime::native::pool::with_threads;
        let mut rng = Rng::new(11);
        let (m, k, n) = (97, 130, 41);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut want = vec![0f32; m * n];
        with_threads(1, || {
            gemm_into(&mut want, &a, &b, m, k, n, false, false, false)
        });
        for nt in [2usize, 3, 8] {
            let mut c = vec![0f32; m * n];
            with_threads(nt, || {
                gemm_into(&mut c, &a, &b, m, k, n, false, false, false)
            });
            assert_eq!(c, want, "nt={nt}");
        }
    }
}
