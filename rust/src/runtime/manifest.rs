//! Artifact manifest: the ABI contract emitted by `python -m compile.aot`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::tensor::{DType, Tensor};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub trainable: bool,
}

#[derive(Debug, Clone)]
pub struct ResInfo {
    pub name: String,
    pub kind: String,
    pub module: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub bits_per_elem: f64,
    pub bytes: u64,
}

#[derive(Debug, Clone)]
pub struct BatchInfo {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone)]
pub struct MergeOp {
    pub norm: String,
    pub linears: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct SelfCheck {
    pub loss: f64,
    pub metric: f64,
    pub grad_l2: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub arch: String,
    pub tuning: String,
    pub activation: String,
    pub norm: String,
    pub dim: usize,
    pub depth: usize,
    pub n_heads: usize,
    pub n_tokens: usize,
    pub batch: usize,
    pub n_classes: usize,
    pub vocab: usize,
    pub mlp_ratio: f64,
    pub lora_rank: usize,
    pub patch_dim: usize,
    pub ckpt: bool,
    pub params: Vec<ParamInfo>,
    pub x: BatchInfo,
    pub y: BatchInfo,
    pub residuals: Vec<ResInfo>,
    pub residual_bytes_total: u64,
    pub merges: Vec<MergeOp>,
    pub selfcheck: SelfCheck,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    Ok(j.as_arr()?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Result<Vec<_>>>()?)
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        let j = Json::parse(&text)?;
        let cfg = j.get("config")?;
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: shape_of(p.get("shape")?)?,
                    trainable: p.get("trainable")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let residuals = j
            .get("residuals")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(ResInfo {
                    name: r.get("name")?.as_str()?.to_string(),
                    kind: r.get("kind")?.as_str()?.to_string(),
                    module: r.get("module")?.as_str()?.to_string(),
                    shape: shape_of(r.get("shape")?)?,
                    dtype: DType::from_manifest(r.get("dtype")?.as_str()?)?,
                    bits_per_elem: r.get("bits_per_elem")?.as_f64()?,
                    bytes: r.get("bytes")?.as_f64()? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let batch = j.get("batch")?;
        let binfo = |k: &str| -> Result<BatchInfo> {
            let b = batch.get(k)?;
            Ok(BatchInfo {
                shape: shape_of(b.get("shape")?)?,
                dtype: DType::from_manifest(b.get("dtype")?.as_str()?)?,
            })
        };
        let merges = j
            .get("merges")?
            .as_arr()?
            .iter()
            .map(|m| {
                Ok(MergeOp {
                    norm: m.get("norm")?.as_str()?.to_string(),
                    linears: m
                        .get("linears")?
                        .as_arr()?
                        .iter()
                        .map(|l| Ok(l.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let sc = j.get("selfcheck")?;
        let selfcheck = SelfCheck {
            loss: sc.get("loss")?.as_f64()?,
            metric: sc.get("metric")?.as_f64()?,
            grad_l2: sc
                .get("grad_l2")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64())
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(Manifest {
            preset: j.get("preset")?.as_str()?.to_string(),
            arch: cfg.get("arch")?.as_str()?.to_string(),
            tuning: cfg.get("tuning")?.as_str()?.to_string(),
            activation: cfg.get("activation")?.as_str()?.to_string(),
            norm: cfg.get("norm")?.as_str()?.to_string(),
            dim: cfg.get("dim")?.as_usize()?,
            depth: cfg.get("depth")?.as_usize()?,
            n_heads: cfg.get("n_heads")?.as_usize()?,
            n_tokens: cfg.get("n_tokens")?.as_usize()?,
            batch: cfg.get("batch")?.as_usize()?,
            n_classes: cfg.get("n_classes")?.as_usize()?,
            vocab: cfg.get("vocab")?.as_usize()?,
            mlp_ratio: cfg.get("mlp_ratio")?.as_f64()?,
            lora_rank: cfg.get("lora_rank")?.as_usize()?,
            patch_dim: cfg.get("patch_dim")?.as_usize()?,
            ckpt: cfg.get("ckpt")?.as_bool()?,
            params,
            x: binfo("x")?,
            y: binfo("y")?,
            residuals,
            residual_bytes_total: j
                .get("residual_bytes_total")?
                .as_f64()? as u64,
            merges,
            selfcheck,
        })
    }

    pub fn trainable_indices(&self) -> Vec<usize> {
        self.params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.trainable)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Load params.bin (f32 LE, concatenated in manifest order).
    pub fn load_params(&self, dir: &Path) -> Result<Vec<Tensor>> {
        let bytes = std::fs::read(dir.join("params.bin"))?;
        let mut off = 0usize;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let n: usize = p.shape.iter().product();
            let nb = n * 4;
            anyhow::ensure!(off + nb <= bytes.len(), "params.bin too small");
            let mut t = Tensor::zeros(&p.shape, DType::F32);
            t.data.copy_from_slice(&bytes[off..off + nb]);
            off += nb;
            out.push(t);
        }
        anyhow::ensure!(off == bytes.len(), "params.bin has trailing bytes");
        Ok(out)
    }

    /// Measured per-category residual bytes (the Figure 2 breakdown,
    /// from the *actual* ABI rather than the analytical model).
    pub fn residual_bytes_by_kind(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = Vec::new();
        for r in &self.residuals {
            match out.iter_mut().find(|(k, _)| *k == r.kind) {
                Some((_, b)) => *b += r.bytes,
                None => out.push((r.kind.clone(), r.bytes)),
            }
        }
        out.sort_by(|a, b| b.1.cmp(&a.1));
        out
    }
}
