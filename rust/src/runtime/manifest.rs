//! Artifact manifest: the ABI contract between the model layer and the
//! coordinator — emitted by `python -m compile.aot` for compiled
//! artifacts, or synthesized by the native backend's dry run.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::tensor::{DType, Tensor};
use crate::util::json::Json;

/// One model parameter: name, shape, and whether it trains.
#[derive(Debug, Clone)]
pub struct ParamInfo {
    /// Dotted module path, e.g. `block0.attn.q.W`.
    pub name: String,
    /// Row-major shape.
    pub shape: Vec<usize>,
    /// Whether the optimizer updates this tensor.
    pub trainable: bool,
}

/// One residual tensor held between fwd and bwd.
#[derive(Debug, Clone)]
pub struct ResInfo {
    /// Unique residual name.
    pub name: String,
    /// Category (`norm_input`, `attn_qkv`, `act_codes`, …) — the
    /// Figure 2 breakdown axis.
    pub kind: String,
    /// Producing module path.
    pub module: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Storage dtype.
    pub dtype: DType,
    /// Effective bits per *logical* element (2.0 for packed codes).
    pub bits_per_elem: f64,
    /// Total storage bytes.
    pub bytes: u64,
}

/// Shape/dtype of one side of the training batch.
#[derive(Debug, Clone)]
pub struct BatchInfo {
    /// Batch tensor shape.
    pub shape: Vec<usize>,
    /// Batch tensor dtype.
    pub dtype: DType,
}

/// One eq. 17 affine merge: the norm whose (α, β) fold into `linears`.
#[derive(Debug, Clone)]
pub struct MergeOp {
    /// Norm module path.
    pub norm: String,
    /// Linear module paths consuming the norm output.
    pub linears: Vec<String>,
}

/// Reference values recorded at export time (or at synthesis dry-run):
/// the loss/metric/grad-norms of one deterministic batch.
#[derive(Debug, Clone)]
pub struct SelfCheck {
    /// Reference loss.
    pub loss: f64,
    /// Reference metric.
    pub metric: f64,
    /// Reference L2 norm per trainable gradient.
    pub grad_l2: Vec<f64>,
}

/// The full artifact manifest (`manifest.json`).
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Preset name.
    pub preset: String,
    /// Architecture tag: `vit` | `llama` | `roberta`.
    pub arch: String,
    /// Tuning tag: `full` | `frozen` | `lora_qv` | ….
    pub tuning: String,
    /// Activation tag: `gelu` | `regelu2` | `silu` | `resilu2` | ….
    pub activation: String,
    /// Norm tag: `ln` | `msln` | `rms` | `msrms` | ….
    pub norm: String,
    /// Embedding width C.
    pub dim: usize,
    /// Transformer depth.
    pub depth: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Tokens per sequence N.
    pub n_tokens: usize,
    /// Batch size B.
    pub batch: usize,
    /// Classifier classes.
    pub n_classes: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// MLP expansion ratio.
    pub mlp_ratio: f64,
    /// LoRA rank.
    pub lora_rank: usize,
    /// ViT patch feature size P.
    pub patch_dim: usize,
    /// Whether the artifact uses gradient checkpointing.
    pub ckpt: bool,
    /// Whether the MLP is the SwiGLU gated form (with RoPE attention).
    /// Optional in `manifest.json` for backward compatibility.
    pub swiglu: bool,
    /// Whether the nonlinear-layer saves are Mesa int8-quantized on
    /// the residual tape (the `_mesa` preset axis). Optional in
    /// `manifest.json` for backward compatibility.
    pub mesa: bool,
    /// Parameter layout, in `params.bin` order.
    pub params: Vec<ParamInfo>,
    /// Input batch contract.
    pub x: BatchInfo,
    /// Target batch contract.
    pub y: BatchInfo,
    /// Residual plan, in fwd-output order.
    pub residuals: Vec<ResInfo>,
    /// Sum of residual bytes — the measured activation memory per step.
    pub residual_bytes_total: u64,
    /// Affine merges for LN→MS-LN checkpoint conversion (eq. 17).
    pub merges: Vec<MergeOp>,
    /// Export-time reference values.
    pub selfcheck: SelfCheck,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Result<Vec<_>>>()
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        Manifest::parse(&text)
    }

    /// Parse manifest JSON text (the `manifest.json` schema). Also the
    /// decoder for the manifest section embedded in artifact
    /// statefiles.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let cfg = j.get("config")?;
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: shape_of(p.get("shape")?)?,
                    trainable: p.get("trainable")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let residuals = j
            .get("residuals")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(ResInfo {
                    name: r.get("name")?.as_str()?.to_string(),
                    kind: r.get("kind")?.as_str()?.to_string(),
                    module: r.get("module")?.as_str()?.to_string(),
                    shape: shape_of(r.get("shape")?)?,
                    dtype: DType::from_manifest(r.get("dtype")?.as_str()?)?,
                    bits_per_elem: r.get("bits_per_elem")?.as_f64()?,
                    bytes: r.get("bytes")?.as_f64()? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let batch = j.get("batch")?;
        let binfo = |k: &str| -> Result<BatchInfo> {
            let b = batch.get(k)?;
            Ok(BatchInfo {
                shape: shape_of(b.get("shape")?)?,
                dtype: DType::from_manifest(b.get("dtype")?.as_str()?)?,
            })
        };
        let merges = j
            .get("merges")?
            .as_arr()?
            .iter()
            .map(|m| {
                Ok(MergeOp {
                    norm: m.get("norm")?.as_str()?.to_string(),
                    linears: m
                        .get("linears")?
                        .as_arr()?
                        .iter()
                        .map(|l| Ok(l.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let sc = j.get("selfcheck")?;
        let selfcheck = SelfCheck {
            loss: sc.get("loss")?.as_f64()?,
            metric: sc.get("metric")?.as_f64()?,
            grad_l2: sc
                .get("grad_l2")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64())
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(Manifest {
            preset: j.get("preset")?.as_str()?.to_string(),
            arch: cfg.get("arch")?.as_str()?.to_string(),
            tuning: cfg.get("tuning")?.as_str()?.to_string(),
            activation: cfg.get("activation")?.as_str()?.to_string(),
            norm: cfg.get("norm")?.as_str()?.to_string(),
            dim: cfg.get("dim")?.as_usize()?,
            depth: cfg.get("depth")?.as_usize()?,
            n_heads: cfg.get("n_heads")?.as_usize()?,
            n_tokens: cfg.get("n_tokens")?.as_usize()?,
            batch: cfg.get("batch")?.as_usize()?,
            n_classes: cfg.get("n_classes")?.as_usize()?,
            vocab: cfg.get("vocab")?.as_usize()?,
            mlp_ratio: cfg.get("mlp_ratio")?.as_f64()?,
            lora_rank: cfg.get("lora_rank")?.as_usize()?,
            patch_dim: cfg.get("patch_dim")?.as_usize()?,
            ckpt: cfg.get("ckpt")?.as_bool()?,
            swiglu: cfg
                .opt("swiglu")
                .map(|v| v.as_bool())
                .transpose()?
                .unwrap_or(false),
            mesa: cfg
                .opt("mesa")
                .map(|v| v.as_bool())
                .transpose()?
                .unwrap_or(false),
            params,
            x: binfo("x")?,
            y: binfo("y")?,
            residuals,
            residual_bytes_total: j
                .get("residual_bytes_total")?
                .as_f64()? as u64,
            merges,
            selfcheck,
        })
    }

    /// Serialize back to the exact JSON schema [`Manifest::parse`]
    /// reads. `parse(m.to_json())` reconstructs every field: strings
    /// and integers round-trip exactly, and the f64 fields
    /// (`mlp_ratio`, `bits_per_elem`, selfcheck values) round-trip
    /// bit-identically because the serializer prints the shortest
    /// representation that re-parses to the same f64.
    pub fn to_json(&self) -> String {
        use crate::util::json::{num, obj, s};
        let shape = |sh: &[usize]| {
            Json::Arr(sh.iter().map(|&d| num(d as f64)).collect())
        };
        let binfo = |b: &BatchInfo| {
            obj(vec![
                ("shape", shape(&b.shape)),
                ("dtype", s(b.dtype.manifest_str())),
            ])
        };
        let j = obj(vec![
            ("preset", s(&self.preset)),
            (
                "config",
                obj(vec![
                    ("arch", s(&self.arch)),
                    ("tuning", s(&self.tuning)),
                    ("activation", s(&self.activation)),
                    ("norm", s(&self.norm)),
                    ("dim", num(self.dim as f64)),
                    ("depth", num(self.depth as f64)),
                    ("n_heads", num(self.n_heads as f64)),
                    ("n_tokens", num(self.n_tokens as f64)),
                    ("batch", num(self.batch as f64)),
                    ("n_classes", num(self.n_classes as f64)),
                    ("vocab", num(self.vocab as f64)),
                    ("mlp_ratio", num(self.mlp_ratio)),
                    ("lora_rank", num(self.lora_rank as f64)),
                    ("patch_dim", num(self.patch_dim as f64)),
                    ("ckpt", Json::Bool(self.ckpt)),
                    ("swiglu", Json::Bool(self.swiglu)),
                    ("mesa", Json::Bool(self.mesa)),
                ]),
            ),
            (
                "params",
                Json::Arr(
                    self.params
                        .iter()
                        .map(|p| {
                            obj(vec![
                                ("name", s(&p.name)),
                                ("shape", shape(&p.shape)),
                                ("trainable", Json::Bool(p.trainable)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "batch",
                obj(vec![("x", binfo(&self.x)), ("y", binfo(&self.y))]),
            ),
            (
                "residuals",
                Json::Arr(
                    self.residuals
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("name", s(&r.name)),
                                ("kind", s(&r.kind)),
                                ("module", s(&r.module)),
                                ("shape", shape(&r.shape)),
                                ("dtype", s(r.dtype.manifest_str())),
                                ("bits_per_elem", num(r.bits_per_elem)),
                                ("bytes", num(r.bytes as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "residual_bytes_total",
                num(self.residual_bytes_total as f64),
            ),
            (
                "merges",
                Json::Arr(
                    self.merges
                        .iter()
                        .map(|m| {
                            obj(vec![
                                ("norm", s(&m.norm)),
                                (
                                    "linears",
                                    Json::Arr(
                                        m.linears
                                            .iter()
                                            .map(|l| s(l))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "selfcheck",
                obj(vec![
                    ("loss", num(self.selfcheck.loss)),
                    ("metric", num(self.selfcheck.metric)),
                    (
                        "grad_l2",
                        Json::Arr(
                            self.selfcheck
                                .grad_l2
                                .iter()
                                .map(|&g| num(g))
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ]);
        j.to_string()
    }

    /// Indices of the trainable parameters, in manifest order — the
    /// order bwd emits gradients in.
    pub fn trainable_indices(&self) -> Vec<usize> {
        self.params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.trainable)
            .map(|(i, _)| i)
            .collect()
    }

    /// Index of a parameter by name.
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Load `params.bin` (f32 LE, concatenated in manifest order).
    pub fn load_params(&self, dir: &Path) -> Result<Vec<Tensor>> {
        let bytes = std::fs::read(dir.join("params.bin"))?;
        let mut off = 0usize;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let n: usize = p.shape.iter().product();
            let nb = n * 4;
            anyhow::ensure!(off + nb <= bytes.len(), "params.bin too small");
            let mut t = Tensor::zeros(&p.shape, DType::F32);
            t.data.copy_from_slice(&bytes[off..off + nb]);
            off += nb;
            out.push(t);
        }
        anyhow::ensure!(off == bytes.len(), "params.bin has trailing bytes");
        Ok(out)
    }

    /// Measured per-category residual bytes (the Figure 2 breakdown,
    /// from the *actual* ABI rather than the analytical model).
    pub fn residual_bytes_by_kind(&self) -> Vec<(String, u64)> {
        let mut out: Vec<(String, u64)> = Vec::new();
        for r in &self.residuals {
            match out.iter_mut().find(|(k, _)| *k == r.kind) {
                Some((_, b)) => *b += r.bytes,
                None => out.push((r.kind.clone(), r.bytes)),
            }
        }
        out.sort_by(|a, b| b.1.cmp(&a.1));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            preset: "p".into(),
            arch: "vit".into(),
            tuning: "lora_qv".into(),
            activation: "regelu2".into(),
            norm: "msln".into(),
            dim: 8,
            depth: 2,
            n_heads: 2,
            n_tokens: 4,
            batch: 3,
            n_classes: 5,
            vocab: 0,
            mlp_ratio: 4.0,
            lora_rank: 2,
            patch_dim: 6,
            ckpt: false,
            swiglu: true,
            mesa: true,
            params: vec![ParamInfo {
                name: "head.W".into(),
                shape: vec![8, 5],
                trainable: true,
            }],
            x: BatchInfo { shape: vec![3, 4, 6], dtype: DType::F32 },
            y: BatchInfo { shape: vec![3], dtype: DType::I32 },
            residuals: vec![ResInfo {
                name: "r0".into(),
                kind: "act_codes".into(),
                module: "block0.mlp".into(),
                shape: vec![3, 4, 32],
                dtype: DType::U8,
                bits_per_elem: 2.0,
                bytes: 96,
            }],
            residual_bytes_total: 96,
            merges: vec![MergeOp {
                norm: "block0.ln1".into(),
                linears: vec!["block0.attn.q".into()],
            }],
            selfcheck: SelfCheck {
                loss: 1.609_437_912_434_100_3,
                metric: 0.2,
                grad_l2: vec![0.5, std::f64::consts::PI],
            },
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let m = sample();
        let m2 = Manifest::parse(&m.to_json()).unwrap();
        assert_eq!(m.preset, m2.preset);
        assert_eq!(m.arch, m2.arch);
        assert_eq!(m.mlp_ratio.to_bits(), m2.mlp_ratio.to_bits());
        assert_eq!(m.swiglu, m2.swiglu);
        assert_eq!(m.mesa, m2.mesa);
        assert_eq!(m.params.len(), m2.params.len());
        assert_eq!(m.params[0].name, m2.params[0].name);
        assert_eq!(m.params[0].shape, m2.params[0].shape);
        assert_eq!(m.x.shape, m2.x.shape);
        assert_eq!(m.y.dtype, m2.y.dtype);
        assert_eq!(m.residuals[0].dtype, m2.residuals[0].dtype);
        assert_eq!(
            m.residuals[0].bits_per_elem.to_bits(),
            m2.residuals[0].bits_per_elem.to_bits()
        );
        assert_eq!(m.residual_bytes_total, m2.residual_bytes_total);
        assert_eq!(m.merges[0].norm, m2.merges[0].norm);
        assert_eq!(m.merges[0].linears, m2.merges[0].linears);
        assert_eq!(
            m.selfcheck.loss.to_bits(),
            m2.selfcheck.loss.to_bits()
        );
        assert_eq!(
            m.selfcheck.grad_l2[1].to_bits(),
            m2.selfcheck.grad_l2[1].to_bits()
        );
        // And the serialization itself is a fixpoint.
        assert_eq!(m.to_json(), m2.to_json());
    }
}
