//! PJRT runtime: load `artifacts/<preset>/{fwd,bwd}.hlo.txt`, compile on
//! the CPU client, execute from the training hot path.
//!
//! Wiring follows /opt/xla-example/load_hlo: HLO *text* interchange (the
//! text parser reassigns the 64-bit instruction ids jax ≥ 0.5 emits that
//! xla_extension 0.5.1 would reject), `return_tuple=True` on the python
//! side, `to_tuple()` here.

pub mod manifest;
pub mod tensor;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use manifest::Manifest;
pub use tensor::{DType, Tensor};

pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }
}

pub struct FwdOut {
    pub loss: f32,
    pub metric: f32,
    pub residuals: Vec<Tensor>,
}

/// A compiled fwd/bwd pair plus its manifest.
pub struct Artifact {
    pub dir: PathBuf,
    pub manifest: Manifest,
    fwd: xla::PjRtLoadedExecutable,
    bwd: xla::PjRtLoadedExecutable,
}

impl Artifact {
    pub fn load(rt: &Runtime, dir: &Path) -> Result<Artifact> {
        let manifest = Manifest::load(dir)?;
        let fwd = compile(rt, &dir.join("fwd.hlo.txt"))
            .with_context(|| format!("compiling fwd for {dir:?}"))?;
        let bwd = compile(rt, &dir.join("bwd.hlo.txt"))
            .with_context(|| format!("compiling bwd for {dir:?}"))?;
        Ok(Artifact { dir: dir.to_path_buf(), manifest, fwd, bwd })
    }

    pub fn load_params(&self) -> Result<Vec<Tensor>> {
        self.manifest.load_params(&self.dir)
    }

    /// Forward pass: (params…, x, y) -> (loss, metric, residuals…).
    pub fn run_fwd(&self, params: &[Tensor], x: &Tensor,
                   y: &Tensor) -> Result<FwdOut> {
        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(params.len() + 2);
        for p in params {
            args.push(p.to_literal()?);
        }
        args.push(x.to_literal()?);
        args.push(y.to_literal()?);
        let bufs = self.fwd.execute::<xla::Literal>(&args)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let mut outs = tuple.to_tuple()?;
        anyhow::ensure!(
            outs.len() == 2 + self.manifest.residuals.len(),
            "fwd arity mismatch: got {}, manifest says {}",
            outs.len(),
            2 + self.manifest.residuals.len()
        );
        let residuals = outs
            .split_off(2)
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        let loss = outs[0].to_vec::<f32>()?[0];
        let metric = outs[1].to_vec::<f32>()?[0];
        Ok(FwdOut { loss, metric, residuals })
    }

    /// Backward pass: (params…, residuals…, x, y) -> grads… (trainables).
    pub fn run_bwd(&self, params: &[Tensor], residuals: &[Tensor],
                   x: &Tensor, y: &Tensor) -> Result<Vec<Tensor>> {
        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(params.len() + residuals.len() + 2);
        for p in params {
            args.push(p.to_literal()?);
        }
        for r in residuals {
            args.push(r.to_literal()?);
        }
        args.push(x.to_literal()?);
        args.push(y.to_literal()?);
        let bufs = self.bwd.execute::<xla::Literal>(&args)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        let n_train = self.manifest.trainable_indices().len();
        anyhow::ensure!(
            outs.len() == n_train,
            "bwd arity mismatch: got {}, expected {n_train}",
            outs.len()
        );
        outs.iter().map(Tensor::from_literal).collect()
    }
}

pub struct FwdOutLit {
    pub loss: f32,
    pub metric: f32,
    pub residuals: Vec<xla::Literal>,
    pub residual_bytes: u64,
}

impl Artifact {
    /// Literal-resident fast path (EXPERIMENTS.md §Perf L3-1): residuals
    /// stay as PJRT literals between fwd and bwd — no host Tensor
    /// materialization. Params are passed as pre-built literals that the
    /// trainer updates in place after each optimizer step.
    pub fn run_fwd_lit(&self, params: &[xla::Literal], x: &xla::Literal,
                       y: &xla::Literal) -> Result<FwdOutLit> {
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(params.len() + 2);
        args.extend(params.iter());
        args.push(x);
        args.push(y);
        let bufs = self.fwd.execute::<&xla::Literal>(&args)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let mut outs = tuple.to_tuple()?;
        anyhow::ensure!(outs.len() == 2 + self.manifest.residuals.len());
        let residuals = outs.split_off(2);
        let residual_bytes =
            residuals.iter().map(|l| l.size_bytes() as u64).sum();
        Ok(FwdOutLit {
            loss: outs[0].to_vec::<f32>()?[0],
            metric: outs[1].to_vec::<f32>()?[0],
            residuals,
            residual_bytes,
        })
    }

    pub fn run_bwd_lit(&self, params: &[xla::Literal],
                       residuals: &[xla::Literal], x: &xla::Literal,
                       y: &xla::Literal) -> Result<Vec<Tensor>> {
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(params.len() + residuals.len() + 2);
        args.extend(params.iter());
        args.extend(residuals.iter());
        args.push(x);
        args.push(y);
        let bufs = self.bwd.execute::<&xla::Literal>(&args)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        outs.iter().map(Tensor::from_literal).collect()
    }
}

fn compile(rt: &Runtime, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 path")?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(rt.client.compile(&comp)?)
}

/// Locate the artifacts directory (repo root or CWD).
pub fn artifacts_dir() -> PathBuf {
    for base in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(base);
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}
