//! Execution runtime: the [`Backend`] abstraction plus its
//! implementations.
//!
//! A backend turns an artifact (a model description + parameters) into an
//! [`Executor`] that runs the decoupled forward/backward pair of the
//! fine-tuning step. Two implementations exist:
//!
//! * [`native`] (default feature `native`): an in-tree pure-Rust CPU
//!   backend that executes the step directly from the manifest —
//!   cache-blocked panel-packed matmuls, multi-head attention,
//!   LN/RMS/MS-LN/MS-RMSNorm, and the ReGELU2/ReSiLU2 forward + 2-bit
//!   packed backward — parallelized with a persistent worker pool, with
//!   a step-scoped buffer arena so steady-state steps allocate nothing.
//!   It can also *synthesize* artifacts for the small named presets, so
//!   nothing outside this crate is needed.
//! * `pjrt` (feature `pjrt`, off by default): loads
//!   `artifacts/<preset>/{fwd,bwd}.hlo.txt` and compiles them through an
//!   external PJRT/XLA client. Enabling the feature requires adding the
//!   `xla` crate to Cargo.toml; see DESIGN.md §2.4.
//!
//! The fwd/bwd **residual ABI** shared by both backends is documented in
//! DESIGN.md §2.2: `fwd(params…, x, y) -> (loss, metric, residuals…)` and
//! `bwd(params…, residuals…, x, y) -> grads…` over the trainable
//! parameters, in manifest order.

pub mod manifest;
#[cfg(feature = "native")]
pub mod native;
pub mod params;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod tensor;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use manifest::Manifest;
pub use params::{FrozenBase, PanelCache, Params};
pub use tensor::{DType, Tensor};

/// Output of one forward pass at the residual ABI.
pub struct FwdOut {
    /// Scalar training loss (mean over the batch).
    pub loss: f32,
    /// Task metric (classification / next-token accuracy).
    pub metric: f32,
    /// The residual tensors held between fwd and bwd — the *measured*
    /// activation memory of the step, in manifest order.
    pub residuals: Vec<Tensor>,
}

/// One session's inputs to a fused forward pass at the split parameter
/// ABI: every job in a [`Executor::run_fwd_split_many`] call shares the
/// same frozen base and differs only in its trainables and batch.
pub struct FwdSplitJob<'a> {
    /// The session's trainable tensors, manifest trainable order.
    pub trainable: &'a [Tensor],
    /// Batch inputs.
    pub x: &'a Tensor,
    /// Batch labels.
    pub y: &'a Tensor,
}

/// One session's inputs to a fused backward pass (see [`FwdSplitJob`]).
pub struct BwdSplitJob<'a> {
    /// The session's trainable tensors, manifest trainable order.
    pub trainable: &'a [Tensor],
    /// The residuals this session's forward pass produced.
    pub residuals: &'a [Tensor],
    /// Batch inputs.
    pub x: &'a Tensor,
    /// Batch labels.
    pub y: &'a Tensor,
}

/// A compiled fwd/bwd pair. Implementations must honor the residual ABI:
/// `run_bwd` receives exactly the residuals `run_fwd` produced.
pub trait Executor {
    /// Forward pass: `(params…, x, y) -> (loss, metric, residuals…)`.
    fn run_fwd(&self, params: &[Tensor], x: &Tensor, y: &Tensor)
        -> Result<FwdOut>;

    /// Backward pass: `(params…, residuals…, x, y) -> grads…` for the
    /// trainable parameters, in `Manifest::trainable_indices` order.
    fn run_bwd(&self, params: &[Tensor], residuals: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<Vec<Tensor>>;

    /// Forward pass at the **split** parameter ABI: an `Arc`-shared
    /// frozen base plus the session's trainable tensors (manifest
    /// trainable order). The default materializes a full flat vector
    /// (cloning the frozen side) and delegates to [`Executor::run_fwd`]
    /// — always correct, but it forfeits the sharing; backends override
    /// it to read the split view zero-copy.
    fn run_fwd_split(&self, base: &FrozenBase, trainable: &[Tensor],
                     x: &Tensor, y: &Tensor) -> Result<FwdOut> {
        let full = Params::Split { base, trainable }.to_vec();
        self.run_fwd(&full, x, y)
    }

    /// Backward pass at the split parameter ABI (see
    /// [`Executor::run_fwd_split`]).
    fn run_bwd_split(&self, base: &FrozenBase, trainable: &[Tensor],
                     residuals: &[Tensor], x: &Tensor,
                     y: &Tensor) -> Result<Vec<Tensor>> {
        let full = Params::Split { base, trainable }.to_vec();
        self.run_bwd(&full, residuals, x, y)
    }

    /// Fused multi-session forward: run every job's forward pass against
    /// the one shared frozen base, returning per-job outputs in job
    /// order. The contract is **bit-identity**: each job's output must
    /// be exactly what [`Executor::run_fwd_split`] would have produced
    /// for it alone — fusion may only change *how* the shared frozen
    /// panels are swept, never any per-job arithmetic. The default runs
    /// the jobs serially (always correct, no fusion win); the native
    /// backend overrides it to walk the layer stack once with all jobs'
    /// activation blocks side by side.
    fn run_fwd_split_many(&self, base: &FrozenBase,
                          jobs: &[FwdSplitJob<'_>])
                          -> Result<Vec<FwdOut>> {
        jobs.iter()
            .map(|j| self.run_fwd_split(base, j.trainable, j.x, j.y))
            .collect()
    }

    /// Fused multi-session backward (see
    /// [`Executor::run_fwd_split_many`] for the bit-identity contract).
    fn run_bwd_split_many(&self, base: &FrozenBase,
                          jobs: &[BwdSplitJob<'_>])
                          -> Result<Vec<Vec<Tensor>>> {
        jobs.iter()
            .map(|j| {
                self.run_bwd_split(base, j.trainable, j.residuals, j.x,
                                   j.y)
            })
            .collect()
    }

    /// Whether this executor reads the split parameter ABI natively
    /// (overrides [`Executor::run_fwd_split`]) rather than relying on
    /// the flat-materializing defaults. A pure capability query — no
    /// allocation; sessions on a `false` backend keep one flat
    /// parameter vector instead of using the split path.
    fn supports_split(&self) -> bool {
        false
    }

    /// Fork an executor that shares this one's compiled model but owns
    /// its own step-scoped state (the native backend's activation
    /// arena), so concurrent sessions never contend on scratch buffers.
    /// `None` when the backend cannot fork — callers then share this
    /// executor, which stays correct (its state is internally locked)
    /// but serializes arena reuse.
    fn fork(&self) -> Option<Box<dyn Executor>> {
        None
    }

    /// Hand step-scoped tensors (the residual list, once the backward
    /// pass has consumed it) back to the executor so their buffers can
    /// be reused next step. Purely an optimization hook — the default
    /// simply drops them, which is always correct.
    fn recycle(&self, residuals: Vec<Tensor>) {
        drop(residuals);
    }
}

/// An execution backend: loads (or synthesizes) artifacts.
pub trait Backend {
    /// Short backend identifier (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Load an artifact directory (`manifest.json` + `params.bin`, plus
    /// backend-specific files such as HLO text for PJRT).
    fn load(&self, dir: &Path) -> Result<Artifact>;

    /// Build an artifact in memory from a named preset spec, with no
    /// files on disk. Backends without synthesis support return an error.
    fn synthesize(&self, preset: &str) -> Result<Artifact> {
        bail!("backend {:?} cannot synthesize preset {preset:?}",
              self.name())
    }

    /// Assemble an artifact from an already-parsed manifest and a full
    /// manifest-ordered parameter vector — the entry point the
    /// statefile loader uses, where both come out of a single `.state`
    /// file instead of a directory. `dir` is a provenance label only
    /// (no files are read from it). Backends that cannot rebuild an
    /// executor from a manifest alone return an error.
    fn assemble(&self, dir: PathBuf, manifest: Manifest,
                params0: Vec<Tensor>) -> Result<Artifact> {
        let _ = (dir, manifest, params0);
        bail!("backend {:?} cannot assemble an artifact from a manifest",
              self.name())
    }
}

/// A backend handle. `Runtime::cpu()` returns the default (native) CPU
/// backend; the PJRT client is selected with `Runtime::from_name("pjrt")`
/// when the `pjrt` feature is enabled.
pub struct Runtime {
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// The default CPU runtime (native backend).
    #[cfg(feature = "native")]
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(native::NativeBackend) })
    }

    /// Select a backend by name: `"native"` (alias `"cpu"`) or `"pjrt"`.
    pub fn from_name(name: &str) -> Result<Runtime> {
        match name {
            #[cfg(feature = "native")]
            "native" | "cpu" => {
                Ok(Runtime { backend: Box::new(native::NativeBackend) })
            }
            #[cfg(feature = "pjrt")]
            "pjrt" => {
                Ok(Runtime { backend: Box::new(pjrt::PjrtBackend::cpu()?) })
            }
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => bail!(
                "backend \"pjrt\" requires building with --features pjrt \
                 (and the external xla crate; see DESIGN.md §2.4)"
            ),
            other => bail!("unknown backend {other:?} (try \"native\")"),
        }
    }

    /// The active backend's identifier.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Assemble an artifact from in-memory parts through the backend
    /// (see [`Backend::assemble`]). Used by the statefile loader.
    pub fn assemble(&self, dir: PathBuf, manifest: Manifest,
                    params0: Vec<Tensor>) -> Result<Artifact> {
        self.backend.assemble(dir, manifest, params0)
    }
}

/// A loaded (or synthesized) fwd/bwd pair plus its manifest and initial
/// parameters.
pub struct Artifact {
    /// Source directory, or `<synthetic>/<preset>` for in-memory specs.
    pub dir: PathBuf,
    /// The ABI contract: parameter layout, residual plan, batch shapes.
    pub manifest: Manifest,
    /// The initial parameters, stored pre-split along the manifest's
    /// trainable/frozen boundary: the frozen side lives behind an
    /// `Arc` that every session clones, so the frozen weights are
    /// resident exactly once in the process no matter how many
    /// sessions fine-tune them (there is no second flat copy).
    frozen: Arc<FrozenBase>,
    trainable0: Vec<Tensor>,
    exec: Box<dyn Executor>,
}

impl Artifact {
    /// Load an artifact directory through the runtime's backend.
    pub fn load(rt: &Runtime, dir: &Path) -> Result<Artifact> {
        rt.backend
            .load(dir)
            .with_context(|| format!("loading artifact {dir:?}"))
    }

    /// Synthesize a named preset through the runtime's backend (native
    /// only); no files are read or written.
    pub fn synth(rt: &Runtime, preset: &str) -> Result<Artifact> {
        rt.backend.synthesize(preset)
    }

    /// Assemble an artifact from parts (used by backend implementations).
    /// `params0` must be in manifest order — every backend produces it
    /// from the manifest itself, so a length mismatch is an API-misuse
    /// bug, not an input-data condition.
    pub fn from_parts(dir: PathBuf, manifest: Manifest,
                      params0: Vec<Tensor>, exec: Box<dyn Executor>)
                      -> Artifact {
        let (frozen, trainable0) = FrozenBase::split(&manifest, params0)
            .expect("artifact params must match the manifest layout");
        Artifact {
            dir,
            manifest,
            frozen: Arc::new(frozen),
            trainable0,
            exec,
        }
    }

    /// The artifact's initial parameters (a fresh copy), manifest order.
    pub fn load_params(&self) -> Result<Vec<Tensor>> {
        Ok(self.frozen.join(self.trainable0.clone()))
    }

    /// The shared frozen base: the read-only parameter population every
    /// session on this artifact shares (an `Arc` onto the artifact's
    /// own storage — cloning the handle copies no tensor data).
    pub fn frozen_base(&self) -> Arc<FrozenBase> {
        self.frozen.clone()
    }

    /// A fresh per-session copy of the trainable tensors, in manifest
    /// trainable order (the order `run_bwd` emits gradients in).
    pub fn trainable_init(&self) -> Vec<Tensor> {
        self.trainable0.clone()
    }

    /// The artifact's own executor (sessions that could not fork run
    /// through it; its step-scoped state is internally locked).
    pub fn executor(&self) -> &dyn Executor {
        self.exec.as_ref()
    }

    /// Fork a session-private executor sharing this artifact's model
    /// (see [`Executor::fork`]).
    pub fn fork_exec(&self) -> Option<Box<dyn Executor>> {
        self.exec.fork()
    }

    /// Whether the backend reads the split parameter ABI natively
    /// (see [`Executor::supports_split`]).
    pub fn supports_split(&self) -> bool {
        self.exec.supports_split()
    }

    /// Check a forward output against the manifest residual plan.
    pub fn verify_fwd(&self, out: &FwdOut) -> Result<()> {
        anyhow::ensure!(
            out.residuals.len() == self.manifest.residuals.len(),
            "fwd arity mismatch: got {}, manifest says {}",
            out.residuals.len(),
            self.manifest.residuals.len()
        );
        Ok(())
    }

    /// Check a gradient list against the manifest trainable count.
    pub fn verify_bwd(&self, grads: &[Tensor]) -> Result<()> {
        let n_train = self.manifest.trainable_indices().len();
        anyhow::ensure!(
            grads.len() == n_train,
            "bwd arity mismatch: got {}, expected {n_train}",
            grads.len()
        );
        Ok(())
    }

    /// Forward pass: `(params…, x, y) -> (loss, metric, residuals…)`.
    pub fn run_fwd(&self, params: &[Tensor], x: &Tensor,
                   y: &Tensor) -> Result<FwdOut> {
        let out = self.exec.run_fwd(params, x, y)?;
        self.verify_fwd(&out)?;
        Ok(out)
    }

    /// Backward pass: `(params…, residuals…, x, y) -> grads…`
    /// (trainables, in manifest order).
    pub fn run_bwd(&self, params: &[Tensor], residuals: &[Tensor],
                   x: &Tensor, y: &Tensor) -> Result<Vec<Tensor>> {
        let grads = self.exec.run_bwd(params, residuals, x, y)?;
        self.verify_bwd(&grads)?;
        Ok(grads)
    }

    /// [`Artifact::run_fwd`] at the split parameter ABI, against the
    /// artifact's own executor.
    pub fn run_fwd_split(&self, base: &FrozenBase, trainable: &[Tensor],
                         x: &Tensor, y: &Tensor) -> Result<FwdOut> {
        let out = self.exec.run_fwd_split(base, trainable, x, y)?;
        self.verify_fwd(&out)?;
        Ok(out)
    }

    /// [`Artifact::run_bwd`] at the split parameter ABI.
    pub fn run_bwd_split(&self, base: &FrozenBase, trainable: &[Tensor],
                         residuals: &[Tensor], x: &Tensor,
                         y: &Tensor) -> Result<Vec<Tensor>> {
        let grads =
            self.exec.run_bwd_split(base, trainable, residuals, x, y)?;
        self.verify_bwd(&grads)?;
        Ok(grads)
    }

    /// [`Artifact::run_fwd_split`] for a gang of sessions through one
    /// fused pass (see [`Executor::run_fwd_split_many`]); outputs are
    /// verified per job.
    pub fn run_fwd_split_many(&self, base: &FrozenBase,
                              jobs: &[FwdSplitJob<'_>])
                              -> Result<Vec<FwdOut>> {
        let outs = self.exec.run_fwd_split_many(base, jobs)?;
        anyhow::ensure!(outs.len() == jobs.len(),
                        "fused fwd arity: got {} outputs for {} jobs",
                        outs.len(), jobs.len());
        for out in &outs {
            self.verify_fwd(out)?;
        }
        Ok(outs)
    }

    /// [`Artifact::run_bwd_split`] for a gang of sessions through one
    /// fused pass; gradient lists are verified per job.
    pub fn run_bwd_split_many(&self, base: &FrozenBase,
                              jobs: &[BwdSplitJob<'_>])
                              -> Result<Vec<Vec<Tensor>>> {
        let outs = self.exec.run_bwd_split_many(base, jobs)?;
        anyhow::ensure!(outs.len() == jobs.len(),
                        "fused bwd arity: got {} outputs for {} jobs",
                        outs.len(), jobs.len());
        for grads in &outs {
            self.verify_bwd(grads)?;
        }
        Ok(outs)
    }

    /// Return a finished step's residual tensors to the executor's
    /// buffer pool (no-op for backends without one). Callers that drop
    /// the residuals instead merely lose the reuse.
    pub fn recycle(&self, residuals: Vec<Tensor>) {
        self.exec.recycle(residuals);
    }
}

/// Locate the artifacts directory (repo root or CWD).
pub fn artifacts_dir() -> PathBuf {
    for base in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(base);
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// Load `preset` from the artifacts directory when it exists on disk,
/// falling back to native synthesis otherwise. This is what lets the CLI
/// and the examples run with zero build-time artifacts.
pub fn load_or_synth(rt: &Runtime, preset: &str) -> Result<Artifact> {
    load_or_synth_in(rt, &artifacts_dir(), preset)
}

/// [`load_or_synth`] against an explicit artifacts base directory (the
/// CLI's `--artifacts` override).
pub fn load_or_synth_in(rt: &Runtime, base: &Path,
                        preset: &str) -> Result<Artifact> {
    let dir = base.join(preset);
    if dir.join("manifest.json").is_file() {
        Artifact::load(rt, &dir)
    } else {
        Artifact::synth(rt, preset).with_context(|| {
            format!(
                "artifact {dir:?} not found and preset {preset:?} is not \
                 synthesizable; build it with:\n  cd python && python -m \
                 compile.aot --out ../artifacts {preset}"
            )
        })
    }
}
