//! Host tensors: dtype-tagged byte buffers bridging manifests, backends,
//! and the optimizer's f32 views.
//!
//! The buffer is a plain `Vec<u8>`; typed access goes through the
//! `as_f32`/`as_i32` views. Backend-specific conversions (e.g. PJRT
//! literals) live with the backend, not here.

use anyhow::{bail, Result};

/// Element type of a [`Tensor`], matching the manifest dtype strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float (`"float32"`).
    F32,
    /// 32-bit signed integer (`"int32"`).
    I32,
    /// 8-bit unsigned integer (`"uint8"`) — packed activation codes.
    U8,
    /// 8-bit signed integer (`"int8"`) — quantized baselines.
    I8,
}

impl DType {
    /// Parse a manifest dtype string (`"float32"`, `"int32"`, …).
    pub fn from_manifest(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "uint8" => DType::U8,
            "int8" => DType::I8,
            _ => bail!("unsupported dtype {s:?}"),
        })
    }

    /// Inverse of [`DType::from_manifest`]: the manifest dtype string.
    pub fn manifest_str(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::U8 => "uint8",
            DType::I8 => "int8",
        }
    }

    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 | DType::I8 => 1,
        }
    }
}

/// A host tensor: shape + dtype + row-major byte buffer.
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// Element type of `data`.
    pub dtype: DType,
    /// Raw little-endian element bytes, `elems() * dtype.size()` long.
    pub data: Vec<u8>,
}

impl Tensor {
    /// All-zero tensor of the given shape and dtype.
    pub fn zeros(shape: &[usize], dtype: DType) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), dtype, data: vec![0; n * dtype.size()] }
    }

    /// f32 tensor from a flat slice (length must match the shape).
    pub fn from_f32(shape: &[usize], v: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(v.len() * 4);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Tensor { shape: shape.to_vec(), dtype: DType::F32, data }
    }

    /// i32 tensor from a flat slice (length must match the shape).
    pub fn from_i32(shape: &[usize], v: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(v.len() * 4);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Tensor { shape: shape.to_vec(), dtype: DType::I32, data }
    }

    /// u8 tensor from a flat slice (length must match the shape).
    pub fn from_u8(shape: &[usize], v: &[u8]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        Tensor { shape: shape.to_vec(), dtype: DType::U8, data: v.to_vec() }
    }

    /// Number of logical elements.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Size of the backing buffer in bytes.
    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    /// View the buffer as `&[f32]`. Panics if the dtype is not `F32`.
    pub fn as_f32(&self) -> &[f32] {
        assert_eq!(self.dtype, DType::F32);
        debug_assert_eq!(self.data.as_ptr() as usize % 4, 0);
        unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const f32,
                self.data.len() / 4,
            )
        }
    }

    /// Mutable f32 view. Panics if the dtype is not `F32`.
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        assert_eq!(self.dtype, DType::F32);
        debug_assert_eq!(self.data.as_ptr() as usize % 4, 0);
        unsafe {
            std::slice::from_raw_parts_mut(
                self.data.as_mut_ptr() as *mut f32,
                self.data.len() / 4,
            )
        }
    }

    /// View the buffer as `&[i32]`. Panics if the dtype is not `I32`.
    pub fn as_i32(&self) -> &[i32] {
        assert_eq!(self.dtype, DType::I32);
        debug_assert_eq!(self.data.as_ptr() as usize % 4, 0);
        unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const i32,
                self.data.len() / 4,
            )
        }
    }

    /// Euclidean norm of an f32 tensor (accumulated in f64).
    pub fn l2(&self) -> f64 {
        self.as_f32().iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_views() {
        let t = Tensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.elems(), 6);
        assert_eq!(t.nbytes(), 24);
        assert_eq!(t.as_f32()[4], 5.0);
    }

    #[test]
    fn mutation_via_view() {
        let mut t = Tensor::zeros(&[4], DType::F32);
        t.as_f32_mut()[2] = 7.5;
        assert_eq!(t.as_f32(), &[0.0, 0.0, 7.5, 0.0]);
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::U8.size(), 1);
        assert_eq!(DType::from_manifest("float32").unwrap(), DType::F32);
        assert!(DType::from_manifest("float64").is_err());
    }

    #[test]
    fn l2_norm() {
        let t = Tensor::from_f32(&[2], &[3.0, 4.0]);
        assert!((t.l2() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn u8_tensor() {
        let t = Tensor::from_u8(&[3], &[1, 2, 3]);
        assert_eq!(t.nbytes(), 3);
        assert_eq!(t.dtype, DType::U8);
    }
}
