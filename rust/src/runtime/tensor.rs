//! Host tensors: dtype-tagged byte buffers bridging manifests, PJRT
//! literals, and the optimizer's f32 views.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
    I8,
}

impl DType {
    pub fn from_manifest(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "uint8" => DType::U8,
            "int8" => DType::I8,
            _ => bail!("unsupported dtype {s:?}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 | DType::I8 => 1,
        }
    }

    pub fn primitive(self) -> xla::PrimitiveType {
        match self {
            DType::F32 => xla::PrimitiveType::F32,
            DType::I32 => xla::PrimitiveType::S32,
            DType::U8 => xla::PrimitiveType::U8,
            DType::I8 => xla::PrimitiveType::S8,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn zeros(shape: &[usize], dtype: DType) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), dtype, data: vec![0; n * dtype.size()] }
    }

    pub fn from_f32(shape: &[usize], v: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(v.len() * 4);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Tensor { shape: shape.to_vec(), dtype: DType::F32, data }
    }

    pub fn from_i32(shape: &[usize], v: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(v.len() * 4);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Tensor { shape: shape.to_vec(), dtype: DType::I32, data }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    pub fn as_f32(&self) -> &[f32] {
        assert_eq!(self.dtype, DType::F32);
        unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const f32,
                self.data.len() / 4,
            )
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        assert_eq!(self.dtype, DType::F32);
        unsafe {
            std::slice::from_raw_parts_mut(
                self.data.as_mut_ptr() as *mut f32,
                self.data.len() / 4,
            )
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        assert_eq!(self.dtype, DType::I32);
        unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const i32,
                self.data.len() / 4,
            )
        }
    }

    /// Convert to a PJRT literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let mut lit = xla::Literal::create_from_shape(
            self.dtype.primitive(),
            &self.shape,
        );
        match self.dtype {
            DType::F32 => lit.copy_raw_from::<f32>(self.as_f32())?,
            DType::I32 => lit.copy_raw_from::<i32>(self.as_i32())?,
            DType::U8 => lit.copy_raw_from::<u8>(&self.data)?,
            DType::I8 => lit.copy_raw_from::<i8>(unsafe {
                std::slice::from_raw_parts(
                    self.data.as_ptr() as *const i8,
                    self.data.len(),
                )
            })?,
        }
        Ok(lit)
    }

    /// Read a PJRT literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|d| *d as usize).collect();
        let dtype = match shape.primitive_type() {
            xla::PrimitiveType::F32 => DType::F32,
            xla::PrimitiveType::S32 => DType::I32,
            xla::PrimitiveType::U8 => DType::U8,
            xla::PrimitiveType::S8 => DType::I8,
            t => bail!("unsupported literal type {t:?}"),
        };
        let mut t = Tensor::zeros(&dims, dtype);
        match dtype {
            DType::F32 => lit.copy_raw_to::<f32>(t.as_f32_mut())?,
            DType::I32 => {
                let n = t.data.len() / 4;
                let sl = unsafe {
                    std::slice::from_raw_parts_mut(
                        t.data.as_mut_ptr() as *mut i32,
                        n,
                    )
                };
                lit.copy_raw_to::<i32>(sl)?;
            }
            DType::U8 => lit.copy_raw_to::<u8>(&mut t.data)?,
            DType::I8 => {
                let sl = unsafe {
                    std::slice::from_raw_parts_mut(
                        t.data.as_mut_ptr() as *mut i8,
                        t.data.len(),
                    )
                };
                lit.copy_raw_to::<i8>(sl)?;
            }
        }
        Ok(t)
    }

    pub fn l2(&self) -> f64 {
        self.as_f32().iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_views() {
        let t = Tensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.elems(), 6);
        assert_eq!(t.nbytes(), 24);
        assert_eq!(t.as_f32()[4], 5.0);
    }

    #[test]
    fn mutation_via_view() {
        let mut t = Tensor::zeros(&[4], DType::F32);
        t.as_f32_mut()[2] = 7.5;
        assert_eq!(t.as_f32(), &[0.0, 0.0, 7.5, 0.0]);
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::U8.size(), 1);
        assert_eq!(DType::from_manifest("float32").unwrap(), DType::F32);
        assert!(DType::from_manifest("float64").is_err());
    }

    #[test]
    fn l2_norm() {
        let t = Tensor::from_f32(&[2], &[3.0, 4.0]);
        assert!((t.l2() - 5.0).abs() < 1e-9);
    }
}
