//! Split parameter ownership along the manifest's trainable/frozen
//! boundary — the multi-tenant sharing contract.
//!
//! A fine-tuning session touches two very different parameter
//! populations: the *frozen base* (embeddings, attention/MLP weights
//! under LoRA, …), which is read-only and identical for every session
//! fine-tuning the same artifact, and the *trainable slice* (LoRA
//! adapters, head, norms under full tuning), which is private per
//! session. [`FrozenBase`] holds the former once — shared across
//! sessions behind an `Arc` — and [`Params`] is the zero-copy view the
//! executors read: either a flat manifest-ordered slice (the classic
//! single-job path) or `base ⊎ trainable` stitched back together by
//! index. N sessions on one base therefore store the base **once**,
//! and the per-session marginal memory is exactly what the paper
//! shrinks: the activation tape, plus the (tiny) trainable slice and
//! its optimizer state.

use std::any::Any;
use std::collections::HashMap;
use std::ops::Index;
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::Tensor;
use crate::util::hash::Fnv64;

/// Step-persistent cache of derived read-only forms of frozen
/// parameters — concretely, the native backend's prepacked GEMM
/// B-panels ([`crate::runtime::native::gemm::PackedB`]), stored
/// type-erased so this module stays backend-independent.
///
/// Safety of the keying: entries are keyed by `(manifest param index,
/// layout flag)` and the cache lives **inside** the [`FrozenBase`]
/// that owns the tensors the entries were derived from. Frozen tensors
/// are immutable for the base's whole lifetime and the cache cannot
/// outlive them, so an entry can never go stale — there is no
/// invalidation path because there is nothing to invalidate. Trainable
/// parameters (which mutate every optimizer step) are *not* cacheable
/// here by construction: they live outside the base.
///
/// The packed panels are derived data and are deliberately **not**
/// part of the admission memmodel (a packed panel is at most one extra
/// copy of the frozen operand, shared by every session on the base);
/// [`PanelCache::nbytes`] reports the residency for observability.
pub struct PanelCache {
    entries: Mutex<HashMap<(usize, bool),
                           (Arc<dyn Any + Send + Sync>, u64)>>,
}

impl Default for PanelCache {
    fn default() -> Self {
        PanelCache::new()
    }
}

impl PanelCache {
    pub fn new() -> PanelCache {
        PanelCache { entries: Mutex::new(HashMap::new()) }
    }

    /// Fetch the cached value for `key`, packing it on first use.
    /// `make` returns the value plus its resident byte count. The lock
    /// is held across `make`, so concurrent sessions racing on a cold
    /// key pack it exactly once.
    pub fn get_or_insert<T, F>(&self, key: (usize, bool),
                               make: F) -> Arc<T>
    where
        T: Any + Send + Sync,
        F: FnOnce() -> (T, u64),
    {
        let mut map =
            self.entries.lock().unwrap_or_else(|e| e.into_inner());
        let (entry, _) = map.entry(key).or_insert_with(|| {
            let (v, bytes) = make();
            (Arc::new(v) as Arc<dyn Any + Send + Sync>, bytes)
        });
        entry
            .clone()
            .downcast::<T>()
            .expect("PanelCache key reused at a different type")
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.entries
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident bytes of the cached derived forms (reported for
    /// observability; excluded from admission accounting — see type
    /// docs).
    pub fn nbytes(&self) -> u64 {
        self.entries
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .values()
            .map(|(_, b)| *b)
            .sum()
    }
}

/// The frozen side of a split parameter set: manifest-ordered slots,
/// `None` where the parameter trains (those live in the per-session
/// trainable vector instead).
pub struct FrozenBase {
    /// `slots[i]` holds parameter `i` iff it is frozen.
    slots: Vec<Option<Tensor>>,
    /// `rank[i]` = position of parameter `i` inside the trainable
    /// vector (valid only where `slots[i]` is `None`).
    rank: Vec<usize>,
    n_trainable: usize,
    nbytes: u64,
    /// Content fingerprint of the frozen tensors (FNV-1a 64 over slot
    /// index, shape, and raw bytes of every frozen slot). Two bases
    /// with the same fingerprint hold bit-identical frozen weights, so
    /// a resumed session may re-attach to an already-resident base
    /// instead of loading a second copy.
    fingerprint: u64,
    /// Derived read-only forms of the frozen tensors (prepacked GEMM
    /// panels). Not serialized, not fingerprinted — pure cache.
    cache: PanelCache,
}

impl FrozenBase {
    /// Partition a full manifest-ordered parameter vector into a
    /// (private) frozen base and the trainable slice, without copying
    /// either side.
    pub fn split(manifest: &Manifest, full: Vec<Tensor>)
                 -> Result<(FrozenBase, Vec<Tensor>)> {
        ensure!(full.len() == manifest.params.len(),
                "param arity: got {}, manifest has {}", full.len(),
                manifest.params.len());
        let mut slots = Vec::with_capacity(manifest.params.len());
        let mut rank = vec![usize::MAX; manifest.params.len()];
        let mut trainable = Vec::new();
        let mut nbytes = 0u64;
        let mut hash = Fnv64::new();
        for (i, (info, t)) in
            manifest.params.iter().zip(full.into_iter()).enumerate()
        {
            if info.trainable {
                rank[i] = trainable.len();
                trainable.push(t);
                slots.push(None);
            } else {
                nbytes += t.nbytes() as u64;
                hash.update(&(i as u64).to_le_bytes());
                for &d in &t.shape {
                    hash.update(&(d as u64).to_le_bytes());
                }
                hash.update(&t.data);
                slots.push(Some(t));
            }
        }
        let n_trainable = trainable.len();
        let fingerprint = hash.finish();
        Ok((
            FrozenBase {
                slots,
                rank,
                n_trainable,
                nbytes,
                fingerprint,
                cache: PanelCache::new(),
            },
            trainable,
        ))
    }

    /// Total number of parameters (frozen + trainable).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether the manifest has no parameters at all.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Number of trainable slots the per-session vector must fill.
    pub fn n_trainable(&self) -> usize {
        self.n_trainable
    }

    /// Resident bytes of the frozen tensors — what N sessions share
    /// and the engine accounts exactly once per base.
    pub fn nbytes(&self) -> u64 {
        self.nbytes
    }

    /// Content fingerprint of the frozen side (see [`FrozenBase`]
    /// field docs). Stable across processes: it hashes only slot
    /// indices, shapes, and raw little-endian tensor bytes.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Frozen tensor at manifest position `i`, `None` where the
    /// parameter trains. Used by the statefile writer to serialize the
    /// base exactly once in manifest order.
    pub fn slot(&self, i: usize) -> Option<&Tensor> {
        self.slots[i].as_ref()
    }

    /// The base's cache of derived frozen-parameter forms (prepacked
    /// GEMM panels). Shared by every session on the base.
    pub fn panel_cache(&self) -> &PanelCache {
        &self.cache
    }

    /// Reassemble a full manifest-ordered parameter vector: frozen
    /// tensors are cloned out of the base (it may be shared), the
    /// trainable vector is moved in by rank.
    pub fn join(&self, trainable: Vec<Tensor>) -> Vec<Tensor> {
        assert_eq!(trainable.len(), self.n_trainable,
                   "trainable arity mismatch");
        let mut moved: Vec<Option<Tensor>> =
            trainable.into_iter().map(Some).collect();
        self.slots
            .iter()
            .enumerate()
            .map(|(i, s)| match s {
                Some(t) => t.clone(),
                None => moved[self.rank[i]]
                    .take()
                    .expect("trainable rank consumed twice"),
            })
            .collect()
    }
}

/// Zero-copy parameter view at the executor ABI: manifest-ordered
/// indexing over either a flat slice or a shared-base/trainable split.
#[derive(Clone, Copy)]
pub enum Params<'a> {
    /// The classic single-job layout: one owned, contiguous vector.
    Flat(&'a [Tensor]),
    /// Multi-tenant layout: `Arc`-shared frozen base + per-session
    /// trainables (in manifest trainable order).
    Split {
        /// The shared frozen side.
        base: &'a FrozenBase,
        /// The session's trainable tensors, `FrozenBase` rank order.
        trainable: &'a [Tensor],
    },
}

impl<'a> Params<'a> {
    /// Number of parameters in manifest order.
    pub fn len(&self) -> usize {
        match self {
            Params::Flat(s) => s.len(),
            Params::Split { base, .. } => base.len(),
        }
    }

    /// Whether the view is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Parameter `i` with the view's full lifetime (not tied to a
    /// borrow of the view itself).
    pub fn get(self, i: usize) -> &'a Tensor {
        match self {
            Params::Flat(s) => &s[i],
            Params::Split { base, trainable } => match &base.slots[i] {
                Some(t) => t,
                None => &trainable[base.rank[i]],
            },
        }
    }

    /// Materialize a full owned vector (clones every tensor) — the
    /// compatibility path for executors that only speak the flat ABI.
    pub fn to_vec(self) -> Vec<Tensor> {
        (0..self.len()).map(|i| self.get(i).clone()).collect()
    }

    /// The panel cache and tensor for parameter `i`, iff the view is a
    /// split view *and* parameter `i` is frozen (lives in the shared
    /// base). `None` for flat views and trainable parameters — both
    /// may mutate between steps, so their derived forms can never be
    /// cached by pointer/index.
    pub fn frozen_cache(self, i: usize)
                        -> Option<(&'a PanelCache, &'a Tensor)> {
        match self {
            Params::Flat(_) => None,
            Params::Split { base, .. } => {
                base.slots[i].as_ref().map(|t| (&base.cache, t))
            }
        }
    }
}

impl Index<usize> for Params<'_> {
    type Output = Tensor;

    fn index(&self, i: usize) -> &Tensor {
        (*self).get(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ParamInfo, SelfCheck};
    use crate::runtime::tensor::DType;
    use crate::runtime::Manifest;

    fn tiny_manifest(trainable: &[bool]) -> Manifest {
        Manifest {
            preset: "t".into(),
            arch: "vit".into(),
            tuning: "lora_qv".into(),
            activation: "gelu".into(),
            norm: "ln".into(),
            dim: 4,
            depth: 1,
            n_heads: 1,
            n_tokens: 2,
            batch: 1,
            n_classes: 2,
            vocab: 0,
            mlp_ratio: 1.0,
            lora_rank: 1,
            patch_dim: 2,
            ckpt: false,
            swiglu: false,
            mesa: false,
            params: trainable
                .iter()
                .enumerate()
                .map(|(i, &t)| ParamInfo {
                    name: format!("p{i}"),
                    shape: vec![2],
                    trainable: t,
                })
                .collect(),
            x: crate::runtime::manifest::BatchInfo {
                shape: vec![1],
                dtype: DType::F32,
            },
            y: crate::runtime::manifest::BatchInfo {
                shape: vec![1],
                dtype: DType::I32,
            },
            residuals: Vec::new(),
            residual_bytes_total: 0,
            merges: Vec::new(),
            selfcheck: SelfCheck {
                loss: 0.0,
                metric: 0.0,
                grad_l2: Vec::new(),
            },
        }
    }

    fn full_params(n: usize) -> Vec<Tensor> {
        (0..n)
            .map(|i| Tensor::from_f32(&[2], &[i as f32, -(i as f32)]))
            .collect()
    }

    #[test]
    fn split_view_matches_flat_view() {
        let m = tiny_manifest(&[false, true, false, true, false]);
        let full = full_params(5);
        let (base, trainable) =
            FrozenBase::split(&m, full.clone()).unwrap();
        assert_eq!(base.n_trainable(), 2);
        assert_eq!(base.nbytes(), 3 * 8);
        let flat = Params::Flat(&full);
        let split = Params::Split { base: &base, trainable: &trainable };
        assert_eq!(flat.len(), split.len());
        for i in 0..5 {
            assert_eq!(flat[i].as_f32(), split[i].as_f32(), "slot {i}");
        }
    }

    #[test]
    fn join_roundtrips_split() {
        let m = tiny_manifest(&[true, false, true]);
        let full = full_params(3);
        let (base, trainable) =
            FrozenBase::split(&m, full.clone()).unwrap();
        let rejoined = base.join(trainable);
        for (a, b) in full.iter().zip(&rejoined) {
            assert_eq!(a.as_f32(), b.as_f32());
        }
    }

    #[test]
    fn split_to_vec_rebuilds_full_set() {
        let m = tiny_manifest(&[true, false]);
        let full = full_params(2);
        let (base, trainable) =
            FrozenBase::split(&m, full.clone()).unwrap();
        assert_eq!(base.n_trainable(), 1);
        assert_eq!(base.nbytes(), 8);
        let v = Params::Split { base: &base, trainable: &trainable }
            .to_vec();
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].as_f32(), full[0].as_f32());
        assert_eq!(v[1].as_f32(), full[1].as_f32());
    }

    #[test]
    fn split_rejects_wrong_arity() {
        let m = tiny_manifest(&[true, false]);
        assert!(FrozenBase::split(&m, full_params(3)).is_err());
    }

    #[test]
    fn panel_cache_packs_once_and_keys_by_index_and_layout() {
        let m = tiny_manifest(&[false, true]);
        let (base, trainable) =
            FrozenBase::split(&m, full_params(2)).unwrap();
        let cache = base.panel_cache();
        assert!(cache.is_empty());
        let mut packs = 0usize;
        for _ in 0..3 {
            let v: Arc<Vec<f32>> = cache.get_or_insert((0, true), || {
                packs += 1;
                (vec![1.0f32, 2.0], 8)
            });
            assert_eq!(*v, vec![1.0, 2.0]);
        }
        assert_eq!(packs, 1, "cold key packs exactly once");
        let _: Arc<Vec<f32>> =
            cache.get_or_insert((0, false), || (vec![3.0f32], 4));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.nbytes(), 12);

        // frozen_cache: Some only for split views on frozen slots
        let split = Params::Split { base: &base, trainable: &trainable };
        assert!(split.frozen_cache(0).is_some());
        assert!(split.frozen_cache(1).is_none(), "trainable slot");
        let full = base.join(trainable);
        assert!(Params::Flat(&full).frozen_cache(0).is_none());
    }

    #[test]
    fn fingerprint_tracks_frozen_content_only() {
        let m = tiny_manifest(&[false, true, false]);
        let (b1, _) = FrozenBase::split(&m, full_params(3)).unwrap();
        let (b2, _) = FrozenBase::split(&m, full_params(3)).unwrap();
        assert_eq!(b1.fingerprint(), b2.fingerprint());

        // Mutating a trainable slot leaves the fingerprint unchanged.
        let mut full = full_params(3);
        full[1].as_f32_mut()[0] = 99.0;
        let (b3, _) = FrozenBase::split(&m, full).unwrap();
        assert_eq!(b1.fingerprint(), b3.fingerprint());

        // Mutating a frozen slot changes it.
        let mut full = full_params(3);
        full[2].as_f32_mut()[0] = 99.0;
        let (b4, _) = FrozenBase::split(&m, full).unwrap();
        assert_ne!(b1.fingerprint(), b4.fingerprint());
    }
}
