//! `ambp` — Approximate & Memory-Sharing Backpropagation (ICML 2024)
//! reproduced as a three-layer rust + JAX + Pallas stack.
//!
//! * L1/L2 live in `python/compile/` (build-time only): Pallas kernels for
//!   ReGELU2/ReSiLU2/MS-LN/MS-RMSNorm and manually-backpropagated
//!   transformer models, AOT-lowered to HLO text.
//! * L3 (this crate) is the fine-tuning coordinator: it loads the HLO
//!   artifacts via PJRT, drives the training loop, owns the optimizer,
//!   data pipeline, metrics, and the *measured* activation-memory
//!   accounting at the fwd/bwd residual ABI.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

pub mod coeffs;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod runtime;
pub mod data;
pub mod memmodel;
pub mod packing;
pub mod quant;
pub mod util;
