//! `ambp` — Approximate & Memory-Sharing Backpropagation (ICML 2024)
//! reproduced as a three-layer rust + JAX + Pallas stack.
//!
//! * L1/L2 live in `python/compile/` (build-time only): Pallas kernels
//!   for ReGELU2/ReSiLU2/MS-LN/MS-RMSNorm and manually-backpropagated
//!   transformer models, AOT-lowered to HLO text.
//! * L3 (this crate) is the fine-tuning coordinator: it drives the
//!   training loop through a pluggable [`runtime::Backend`] — the
//!   default in-tree `native` CPU backend executes the decoupled
//!   fwd/bwd step directly from the manifest (no XLA, no network); the
//!   optional `pjrt` feature loads the AOT HLO artifacts instead. The
//!   coordinator owns the optimizer, data pipeline, metrics, and the
//!   *measured* activation-memory accounting at the fwd/bwd residual
//!   ABI.
//!
//! See DESIGN.md for the system inventory, the `Backend` trait contract,
//! the residual ABI, and the per-experiment index.

// The crate predates clippy adoption in CI; these style lints fire on
// long-standing idioms (index loops over multiple slices, the in-tree
// Json::to_string) and are intentionally allowed crate-wide.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::inherent_to_string,
    clippy::new_without_default,
    clippy::manual_div_ceil
)]

pub mod coeffs;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod memmodel;
pub mod packing;
pub mod quant;
pub mod runtime;
pub mod util;
