//! Memory report: measured (small artifacts) vs analytical (paper scale).
//!
//! Prints the Figure 2 composition for ViT-B and LLaMA-13B, the Figure
//! 5/6 per-block unit tallies, and — when artifacts are built — the
//! *measured* residual breakdown of the small presets next to the
//! memmodel's tape-mode prediction for the same dims (they must agree).
//!
//!   make artifacts && cargo run --release --example memory_report

use ambp::memmodel::ops::{ActKind, Arch, MemCfg, Mode, NormKind, Tuning};
use ambp::memmodel::report::composition_rows;
use ambp::memmodel::{block_units, presets as mp, total_bytes};
use anyhow::Result;

fn main() -> Result<()> {
    println!("── Figure 5/6: per-block activation units ──");
    for (label, cfg) in [
        ("ViT trainable (GELU+LN)  [paper 19.0]",
         mp::vit_base(64, Tuning::Full, ActKind::Gelu, NormKind::Ln)),
        ("ViT frozen    (GELU+LN)  [paper 12.0]",
         mp::vit_base(64, Tuning::Frozen, ActKind::Gelu, NormKind::Ln)),
        ("ViT ours (ReGELU2+MS-LN) [paper 11.5]",
         mp::vit_base(64, Tuning::Full, ActKind::ReGelu2, NormKind::MsLn)),
        ("LLaMA-13B trainable      [paper 21.8]",
         mp::llama13b(4, 2048, ActKind::Silu, NormKind::Rms)),
        ("LLaMA-13B ours           [paper 15.44]",
         mp::llama13b(4, 2048, ActKind::ReSilu2, NormKind::MsRms)),
    ] {
        println!("  {label:<42} {:>6.2} units", block_units(&cfg));
    }

    println!("\n── Figure 2: composition (analytical, paper mode) ──");
    for (label, cfg) in [
        ("ViT-B LoRA", mp::vit_base(64, Tuning::LoraQv, ActKind::Gelu,
                                    NormKind::Ln)),
        ("LLaMA-13B", mp::llama13b(4, 2048, ActKind::Silu, NormKind::Rms)),
    ] {
        println!("  {label}:");
        for (cat, pct) in composition_rows(&cfg) {
            println!("    {cat:<16} {pct:>5.1}%");
        }
    }

    // measured vs analytical cross-check on the small presets (on-disk
    // artifacts when built, native synthesis otherwise)
    println!("\n── measured (manifest) vs memmodel tape-mode ──");
    let rt = ambp::runtime::Runtime::cpu()?;
    for preset in ["vitt_loraqv_gelu_ln", "vitt_loraqv_regelu2_msln",
                   "llama_loraall_silu_rms"] {
        let art = ambp::runtime::load_or_synth(&rt, preset)?;
        let m = &art.manifest;
        let cfg = MemCfg {
            arch: match m.arch.as_str() {
                "llama" => Arch::Llama,
                "roberta" => Arch::Roberta,
                _ => Arch::Vit,
            },
            dim: m.dim,
            depth: m.depth,
            n_heads: m.n_heads,
            mlp_ratio: m.mlp_ratio,
            n_tokens: m.n_tokens,
            patch_dim: m.patch_dim,
            n_classes: m.n_classes,
            vocab: m.vocab,
            lora_rank: m.lora_rank,
            batch: m.batch,
            tuning: ambp::exp::helpers::tuning_kind(&m.tuning),
            act: ambp::exp::helpers::act_kind(&m.activation),
            norm: ambp::exp::helpers::norm_kind(&m.norm),
            mode: Mode::Tape,
            ckpt: m.ckpt,
            mesa: m.mesa,
        };
        let predicted = total_bytes(&cfg);
        let measured = m.residual_bytes_total;
        let err = 100.0 * (predicted as f64 - measured as f64)
            / measured as f64;
        println!("  {preset:<28} measured {:>9.2} MiB | model {:>9.2} MiB \
                  | Δ {err:+.1}%",
                 measured as f64 / 1048576.0,
                 predicted as f64 / 1048576.0);
    }
    Ok(())
}
