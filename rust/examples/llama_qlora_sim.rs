//! QLoRA-style LLaMA fine-tuning simulation (the Table 3 workflow):
//! LoRA-all over a frozen LLaMA-style decoder on the synthetic
//! instruction corpus, with NF4 weight-storage accounting, comparing
//! {SiLU, RMSNorm} against {ReSiLU2, MS-RMSNorm}.
//!
//!   cargo run --release --example llama_qlora_sim [-- --steps 120]

use ambp::coordinator::{TrainCfg, Trainer};
use ambp::quant::nf4;
use ambp::runtime::{load_or_synth, Runtime};
use ambp::util::cli::Args;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 100)?;
    let rt = Runtime::cpu()?;

    let mut rows = Vec::new();
    for (label, preset) in [
        ("SiLU + RMSNorm", "llama_loraall_silu_rms"),
        ("ReSiLU2 + MS-RMSNorm", "llama_loraall_resilu2_msrms"),
    ] {
        println!("\n=== {label} ({preset}) ===");
        let art = load_or_synth(&rt, preset)?;
        // NF4 weight-storage accounting for the frozen base weights
        // (QLoRA stores them in NF4; the LoRA adapters stay f32)
        let tidx = art.manifest.trainable_indices();
        let frozen_elems: usize = art
            .manifest
            .params
            .iter()
            .enumerate()
            .filter(|(i, _)| !tidx.contains(i))
            .map(|(_, p)| p.shape.iter().product::<usize>())
            .sum();
        let nf4_bytes = frozen_elems as f64 * nf4::bits_per_elem(64) / 8.0;
        println!("frozen base: {:.2}M params → {:.1} MiB as NF4 \
                  (vs {:.1} MiB f32)",
                 frozen_elems as f64 / 1e6, nf4_bytes / 1048576.0,
                 frozen_elems as f64 * 4.0 / 1048576.0);
        // demonstrate the codec on a real weight tensor
        let params = art.load_params()?;
        let w = &params[art.manifest.param_index("block0.attn.q.W")
                        .expect("q.W")];
        let q = nf4::quantize(w.as_f32(), 64);
        let deq = nf4::dequantize(&q);
        let rel: f64 = {
            let num: f64 = w.as_f32().iter().zip(&deq)
                .map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let den: f64 = w.as_f32().iter()
                .map(|a| (*a as f64).powi(2)).sum();
            (num / den).sqrt()
        };
        println!("NF4 round-trip rel-RMS error on q.W: {rel:.4}");

        let mut tr = Trainer::new(&art, TrainCfg {
            steps,
            lr: 2e-3,
            seed: 11,
            log_every: 25,
            grad_accum: 2, // paper: bs 4 × accum 4
            ..Default::default()
        })?;
        let rep = tr.train()?;
        println!(
            "{label}: loss {:.4} → eval token-acc {:.3}, {:.1} seq/s, \
             activation {:.1} MiB",
            rep.final_loss, rep.eval_metric, rep.throughput,
            rep.peak_activation_bytes as f64 / 1048576.0
        );
        rows.push((label, rep, nf4_bytes));
    }

    println!("\n=== QLoRA-sim summary (Table 3 shape) ===");
    let base_act = rows[0].1.peak_activation_bytes as f64;
    for (label, rep, nf4_bytes) in &rows {
        let act = rep.peak_activation_bytes as f64;
        println!(
            "{label:<24} token-acc {:.3}  act {:>7.1} MiB ({:+.0}%)  \
             +NF4 weights {:>6.1} MiB  thr {:>5.1} seq/s",
            rep.eval_metric,
            act / 1048576.0,
            100.0 * (act / base_act - 1.0),
            nf4_bytes / 1048576.0,
            rep.throughput
        );
    }
    Ok(())
}
