//! Quickstart: load (or synthesize) an artifact, run a few fine-tuning
//! steps, show the measured activation memory. Works offline with zero
//! build-time artifacts — the native backend synthesizes the presets.
//!
//!   cargo run --release --example quickstart

use ambp::coordinator::{TrainCfg, Trainer};
use ambp::runtime::{load_or_synth, Runtime};
use anyhow::Result;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    for preset in ["vitt_loraqv_gelu_ln", "vitt_loraqv_regelu2_msln"] {
        let art = load_or_synth(&rt, preset)?;
        let m = &art.manifest;
        println!(
            "\n{preset}: {} ({}, act={}, norm={})",
            m.arch, m.tuning, m.activation, m.norm
        );

        // three training steps, then report the measured residual bytes —
        // the paper's "activation memory", observed at the fwd/bwd ABI
        let mut trainer = Trainer::new(
            &art,
            TrainCfg { steps: 3, lr: 1e-3, log_every: 1,
                       ..Default::default() },
        )?;
        let rep = trainer.train()?;
        println!(
            "loss {:.4} → eval acc {:.3} | activation memory {:.2} MiB",
            rep.final_loss,
            rep.eval_metric,
            rep.peak_activation_bytes as f64 / 1048576.0
        );
        for (kind, bytes) in &rep.by_kind {
            println!("   {:<13} {:>8.2} MiB", kind,
                     *bytes as f64 / 1048576.0);
        }
    }
    println!("\nReGELU2 turns the act_full tensor into 2-bit act_codes; \
              MS-LN removes norm_input entirely (shares z with q/k/v).");
    Ok(())
}
