//! End-to-end driver (DESIGN.md §4, EXPERIMENTS.md §E2E):
//! pretrain a ViT-style model full-tuning on task A, then LoRA-fine-tune
//! it on task B twice — with {GELU, LN} and with {ReGELU2, MS-LN} — from
//! the SAME pretrained checkpoint (affine-merged per eq. 17 for MS-LN).
//!
//! Logs both loss curves, final accuracy, throughput, and the measured
//! activation-memory gap. This is the full paper workflow: pretrained
//! weights → memory-efficient fine-tuning with an unchanged forward pass.
//!
//!   cargo run --release --example vit_lora_finetune \
//!       [-- --pretrain-steps 120 --steps 200]

use std::path::PathBuf;

use ambp::coordinator::checkpoint::{merge_affine, Checkpoint};
use ambp::coordinator::scheduler::Schedule;
use ambp::coordinator::{TrainCfg, Trainer};
use ambp::runtime::{load_or_synth, Runtime};
use ambp::util::cli::Args;
use anyhow::Result;

fn main() -> Result<()> {
    let args = Args::from_env();
    let pretrain_steps = args.usize_or("pretrain-steps", 80)?;
    let steps = args.usize_or("steps", 150)?;
    let rt = Runtime::cpu()?;
    let out = PathBuf::from("target/e2e");
    std::fs::create_dir_all(&out)?;

    // ---- phase 1: "pretrain" (full tuning, task seed 100) --------------
    println!("=== phase 1: pretrain vitt (full tuning, GELU+LN) ===");
    let pre = load_or_synth(&rt, "vitt_full_gelu_ln")?;
    let n_params: usize =
        pre.manifest.params.iter()
            .map(|p| p.shape.iter().product::<usize>()).sum();
    println!("model: {:.1}M params, depth {}, dim {}",
             n_params as f64 / 1e6, pre.manifest.depth, pre.manifest.dim);
    let mut t = Trainer::new(&pre, TrainCfg {
        steps: pretrain_steps,
        lr: 3e-4,
        seed: 100,
        log_every: 20,
        metrics_jsonl: Some(out.join("pretrain.jsonl")),
        ..Default::default()
    })?;
    let rep = t.train()?;
    println!("pretrain: loss {:.4}, acc {:.3}, {:.1} img/s",
             rep.final_loss, rep.eval_metric, rep.throughput);
    let ck = Checkpoint::from_params(&pre.manifest, &t.params);
    ck.save(&out.join("pretrained"))?;

    // ---- phase 2: LoRA fine-tune on task B, both variants --------------
    let mut results = Vec::new();
    for (label, preset, merge) in [
        ("LoRA + GELU + LN", "vitt_loraqv_gelu_ln", false),
        ("LoRA + ReGELU2 + MS-LN", "vitt_loraqv_regelu2_msln", true),
    ] {
        println!("\n=== phase 2: fine-tune {label} ===");
        let art = load_or_synth(&rt, preset)?;
        let mut tr = Trainer::new(&art, TrainCfg {
            steps,
            lr: 1.25e-3,
            seed: 7, // task B
            log_every: 25,
            schedule: Schedule::WarmupCosine {
                warmup: steps / 10,
                warmup_init: 1e-6,
            },
            metrics_jsonl: Some(out.join(format!("{preset}.jsonl"))),
            ..Default::default()
        })?;
        // restore pretrained weights (merged for the MS-LN variant)
        let restored = if merge {
            merge_affine(&ck, &art.manifest)?
                .restore(&art.manifest, &mut tr.params)?
        } else {
            ck.restore(&art.manifest, &mut tr.params)?
        };
        println!("restored {restored} pretrained tensors \
                  (LoRA adapters fresh)");
        let rep = tr.train()?;
        println!(
            "{label}: loss {:.4}, eval acc {:.3}, {:.1} img/s, \
             activation {:.1} MiB",
            rep.final_loss, rep.eval_metric, rep.throughput,
            rep.peak_activation_bytes as f64 / 1048576.0
        );
        results.push((label, rep));
    }

    // ---- summary --------------------------------------------------------
    println!("\n=== e2e summary (full workflow: pretrain → LoRA) ===");
    let base = &results[0].1;
    for (label, rep) in &results {
        println!(
            "{label:<24} acc {:.3}  act-mem {:>7.1} MiB ({:+.0}%)  \
             thr {:>6.1} img/s ({:+.0}%)",
            rep.eval_metric,
            rep.peak_activation_bytes as f64 / 1048576.0,
            100.0 * (rep.peak_activation_bytes as f64
                / base.peak_activation_bytes as f64 - 1.0),
            rep.throughput,
            100.0 * (rep.throughput / base.throughput - 1.0),
        );
    }
    println!("\nloss curves in target/e2e/*.jsonl");
    Ok(())
}
