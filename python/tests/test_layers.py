"""Per-layer manual-backprop gradient checks against jax autodiff."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.layers import Activation, Alloc, Linear, Norm
from compile.tape import Tape, TapeReader
from compile.kernels import coeffs, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, scale=1.0):
    return jnp.asarray(
        (np.random.RandomState(seed).randn(*shape) * scale).astype("float32"))


def _params(alloc, seed=7):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(s.materialize(rng)) for s in alloc.specs]


def _run(layer_fwd, layer_bwd, P, x, gy):
    """fwd -> tape -> bwd; returns (y, gx, grads)."""
    tape = Tape()
    y = layer_fwd(P, tape, x)
    gx, grads = layer_bwd(P, TapeReader(tape.vals), gy)
    return y, gx, grads, tape


class TestLinearModes:
    @pytest.mark.parametrize("mode", ["full", "frozen", "lora", "lorafa"])
    def test_grad_matches_autodiff(self, mode):
        alloc = Alloc()
        lin = Linear(alloc, "l", 12, 8, mode)
        P = _params(alloc)
        x, gy = _rand((5, 12), 1), _rand((5, 8), 2)
        y, gx, grads, _ = _run(lin.fwd, lin.bwd, P, x, gy)

        def f(P_, x_):
            t = Tape()
            return jnp.vdot(lin.fwd(P_, t, x_), gy)

        gP, gx_want = jax.grad(f, argnums=(0, 1))(P, x)
        np.testing.assert_allclose(gx, gx_want, atol=1e-5)
        for i, s in enumerate(alloc.specs):
            if s.trainable:
                assert i in grads, f"missing grad for {s.name}"
                np.testing.assert_allclose(grads[i], gP[i], atol=1e-5)
            else:
                assert i not in grads

    def test_residual_policy(self):
        """What each mode saves is exactly the §3.2 story."""
        for mode, kinds in [
            ("full", {"linear_input"}),
            ("frozen", set()),
            ("lora", {"linear_input", "lora_u"}),
            ("lorafa", {"lora_u"}),
        ]:
            alloc = Alloc()
            lin = Linear(alloc, "l", 12, 8, mode)
            P = _params(alloc)
            tape = Tape()
            lin.fwd(P, tape, _rand((5, 12)))
            assert {s.kind for s in tape.specs} == kinds, mode

    def test_shared_input_not_resaved(self):
        alloc = Alloc()
        lin = Linear(alloc, "l", 12, 8, "lora")
        P = _params(alloc)
        tape = Tape()
        x = _rand((5, 12))
        z_idx = tape.save("norm", "z", "norm_shared", x)
        lin.fwd(P, tape, x, shared_x_idx=z_idx)
        kinds = [s.kind for s in tape.specs]
        assert "linear_input" not in kinds  # reused the shared z
        # and bwd still works
        gx, grads = lin.bwd(P, TapeReader(tape.vals), _rand((5, 8)))
        assert gx.shape == x.shape

    def test_lora_starts_as_identity(self):
        """B = 0 init: LoRA output equals the frozen projection at t=0."""
        alloc = Alloc()
        lin = Linear(alloc, "l", 12, 8, "lora")
        alloc2 = Alloc()
        frz = Linear(alloc2, "l", 12, 8, "frozen")
        P = _params(alloc)
        P2 = _params(alloc2)
        x = _rand((5, 12))
        y1 = lin.fwd(P, Tape(), x)
        y2 = frz.fwd(P2, Tape(), x)
        np.testing.assert_allclose(y1, y2, atol=1e-6)


class TestActivationLayers:
    @pytest.mark.parametrize("kind", ["gelu", "silu", "relu"])
    def test_exact_backward(self, kind):
        act = Activation("a", kind)
        x, gy = _rand((6, 16), 3, 2.0), _rand((6, 16), 4)
        tape = Tape()
        y = act.fwd(tape, x)
        gx = act.bwd(TapeReader(tape.vals), gy)
        f = {"gelu": ref.gelu, "silu": ref.silu, "relu": ref.relu}[kind]
        _, vjp = jax.vjp(f, x)
        np.testing.assert_allclose(gx, vjp(gy)[0], atol=1e-5)

    @pytest.mark.parametrize("kind", ["regelu2", "regelu2d", "resilu2"])
    def test_approx_backward_is_surrogate_derivative(self, kind):
        act = Activation("a", kind)
        x, gy = _rand((6, 16), 5, 3.0), _rand((6, 16), 6)
        tape = Tape()
        y = act.fwd(tape, x)
        # forward is EXACT (the paper's key design point, Appendix C)
        exact = ref.gelu(x) if kind.startswith("regelu") else ref.silu(x)
        np.testing.assert_allclose(y, exact, atol=1e-6)
        gx = act.bwd(TapeReader(tape.vals), gy)
        a, c = coeffs.BY_NAME[kind]
        np.testing.assert_allclose(gx, gy * ref.drelu_comb(x, a, c),
                                   atol=1e-6)

    @pytest.mark.parametrize("kind,bits", [
        ("gelu", 32), ("silu", 32), ("relu", 1),
        ("regelu2", 2), ("resilu2", 2), ("mesa_gelu8", 8)])
    def test_residual_bits(self, kind, bits):
        act = Activation("a", kind)
        x = _rand((8, 32), 7)
        tape = Tape()
        act.fwd(tape, x)
        main = tape.specs[0]
        assert main.bits_per_logical_elem == bits

    def test_mesa_backward_close_to_exact(self):
        act = Activation("a", "mesa_gelu8")
        x, gy = _rand((6, 16), 8, 2.0), _rand((6, 16), 9)
        tape = Tape()
        act.fwd(tape, x)
        gx = act.bwd(TapeReader(tape.vals), gy)
        np.testing.assert_allclose(gx, gy * ref.dgelu(x), atol=0.05)


class TestNormLayers:
    @pytest.mark.parametrize("kind", ["ln", "rms"])
    def test_exact_backward(self, kind):
        alloc = Alloc()
        nrm = Norm(alloc, "n", 16, kind, affine_trainable=True)
        P = _params(alloc)
        x, gy = _rand((6, 16), 10), _rand((6, 16), 11)
        y, gx, grads, _ = _run(nrm.fwd, nrm.bwd, P, x, gy)

        def f(P_, x_):
            return jnp.vdot(nrm.fwd(P_, Tape(), x_), gy)

        gP, gx_want = jax.grad(f, argnums=(0, 1))(P, x)
        np.testing.assert_allclose(gx, gx_want, atol=1e-5)
        for i, s in enumerate(alloc.specs):
            np.testing.assert_allclose(grads[i], gP[i], atol=1e-5)

    @pytest.mark.parametrize("kind", ["msln", "msrms"])
    def test_ms_backward(self, kind):
        alloc = Alloc()
        nrm = Norm(alloc, "n", 16, kind, affine_trainable=False)
        P = _params(alloc)
        x, gy = _rand((6, 16), 12), _rand((6, 16), 13)
        y, gx, grads, tape = _run(nrm.fwd, nrm.bwd, P, x, gy)
        assert grads == {}  # MS variants have no params (merged, eq. 17)
        assert nrm.shared_out_idx is not None

        def f(x_):
            return jnp.vdot(nrm.fwd(P, Tape(), x_), gy)

        gx_want = jax.grad(f)(x)
        np.testing.assert_allclose(gx, gx_want, atol=1e-5)

    def test_merged_equivalence(self):
        """eq. 16→18: LN+affine+linear == MS-LN + merged linear."""
        p = 16
        rng = np.random.RandomState(0)
        alpha = jnp.asarray(rng.randn(p).astype("float32"))
        beta = jnp.asarray(rng.randn(p).astype("float32"))
        W = jnp.asarray(rng.randn(8, p).astype("float32"))
        b = jnp.asarray(rng.randn(8).astype("float32"))
        x = _rand((5, p), 14)
        y_ln, _, _ = ref.ln_fwd(x, alpha, beta)
        y1 = y_ln @ W.T + b
        z, _ = ref.msln_fwd(x)
        Wm = W * alpha[None, :]
        bm = W @ beta + b
        y2 = z @ Wm.T + bm
        np.testing.assert_allclose(y1, y2, atol=1e-4)

    def test_rms_merged_equivalence(self):
        p = 16
        rng = np.random.RandomState(1)
        alpha = jnp.asarray(rng.randn(p).astype("float32"))
        W = jnp.asarray(rng.randn(8, p).astype("float32"))
        x = _rand((5, p), 15)
        y_rms, _ = ref.rms_fwd(x, alpha)
        y1 = y_rms @ W.T
        z, _ = ref.msrms_fwd(x)
        y2 = z @ (W * alpha[None, :]).T
        np.testing.assert_allclose(y1, y2, atol=1e-4)
