"""Block-level gradient checks + residual-sharing chain invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.blocks import AttnBlock, MlpBlock, SwiGluBlock
from compile.layers import Alloc
from compile.tape import Tape, TapeReader

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape)
                       .astype("float32"))


def _params(alloc, seed=3):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(s.materialize(rng)) for s in alloc.specs]


def _gradcheck_block(blk, alloc, x, seed=5, tol=2e-4):
    P = _params(alloc)
    gy = _rand(x.shape, seed)
    tape = Tape()
    y = blk.fwd(P, tape, x)
    gx, grads = blk.bwd(P, TapeReader(tape.vals), gy)

    def f(P_, x_):
        return jnp.vdot(blk.fwd(P_, Tape(), x_), gy)

    gP, gx_want = jax.grad(f, argnums=(0, 1))(P, x)
    np.testing.assert_allclose(gx, gx_want, atol=tol)
    for i, s in enumerate(alloc.specs):
        if s.trainable:
            np.testing.assert_allclose(grads[i], gP[i], atol=tol,
                                       err_msg=s.name)
    return tape


@pytest.mark.parametrize("tuning", ["full", "lora_qv", "lora_all",
                                    "lorafa_all", "frozen"])
@pytest.mark.parametrize("norm", ["ln", "msln"])
def test_attn_block_grads(tuning, norm):
    alloc = Alloc()
    blk = AttnBlock(alloc, "b.attn", 16, 2, tuning, norm)
    if tuning == "frozen":
        # no trainables: just check it runs and gx matches autodiff
        P = _params(alloc)
        x = _rand((2, 4, 16), 1)
        tape = Tape()
        blk.fwd(P, tape, x)
        gx, grads = blk.bwd(P, TapeReader(tape.vals), _rand(x.shape, 2))
        assert grads == {}
        return
    _gradcheck_block(blk, alloc, _rand((2, 4, 16), 1))


@pytest.mark.parametrize("act", ["gelu", "regelu2", "relu", "mesa_gelu8"])
def test_mlp_block_grads(act):
    alloc = Alloc()
    blk = MlpBlock(alloc, "b.mlp", 16, 32, "lora_all", "msln", act)
    tol = 2e-3 if act == "mesa_gelu8" else 2e-4
    P = _params(alloc)
    x = _rand((2, 4, 16), 7)
    gy = _rand(x.shape, 8)
    tape = Tape()
    blk.fwd(P, tape, x)
    gx, grads = blk.bwd(P, TapeReader(tape.vals), gy)
    if act in ("gelu", "relu", "mesa_gelu8"):
        def f(P_, x_):
            return jnp.vdot(blk.fwd(P_, Tape(), x_), gy)
        gP, gx_want = jax.grad(f, argnums=(0, 1))(P, x)
        np.testing.assert_allclose(gx, gx_want, atol=tol)


@pytest.mark.parametrize("act", ["silu", "resilu2"])
def test_swiglu_block_grads(act):
    alloc = Alloc()
    blk = SwiGluBlock(alloc, "b.mlp", 16, 40, "lora_all", "msrms", act)
    P = _params(alloc)
    x = _rand((2, 4, 16), 9)
    gy = _rand(x.shape, 10)
    tape = Tape()
    blk.fwd(P, tape, x)
    gx, grads = blk.bwd(P, TapeReader(tape.vals), gy)
    if act == "silu":
        def f(P_, x_):
            return jnp.vdot(blk.fwd(P_, Tape(), x_), gy)
        gP, gx_want = jax.grad(f, argnums=(0, 1))(P, x)
        np.testing.assert_allclose(gx, gx_want, atol=2e-4)
        for i, s in enumerate(alloc.specs):
            if s.trainable:
                np.testing.assert_allclose(grads[i], gP[i], atol=2e-4,
                                           err_msg=s.name)


class TestSharingChains:
    def _tape_kinds(self, tuning, norm, arch="attn"):
        alloc = Alloc()
        if arch == "attn":
            blk = AttnBlock(alloc, "b", 16, 2, tuning, norm)
            x = _rand((2, 4, 16), 11)
        else:
            blk = SwiGluBlock(alloc, "b", 16, 40, tuning, norm, "silu")
            x = _rand((2, 4, 16), 11)
        P = _params(alloc)
        tape = Tape()
        blk.fwd(P, tape, x)
        return [s.kind for s in tape.specs]

    def test_qkv_share_one_input_copy(self):
        """q,k,v consume one stored z — exactly one linear_input (LN)."""
        kinds = self._tape_kinds("lora_all", "ln")
        assert kinds.count("linear_input") == 2  # z (shared) + proj input

    def test_msnorm_removes_linear_input(self):
        """With MS-LN, z comes from norm_shared; only proj saves input."""
        kinds = self._tape_kinds("lora_all", "msln")
        assert kinds.count("norm_shared") == 1
        assert kinds.count("linear_input") == 1  # proj only
        assert "norm_input" not in kinds

    def test_swiglu_fc12_share(self):
        kinds = self._tape_kinds("lora_all", "rms", arch="swiglu")
        # fc1+fc2 share z (1) + fc3 input (1)
        assert kinds.count("linear_input") == 2

    def test_frozen_saves_nothing_linear(self):
        kinds = self._tape_kinds("frozen", "ln")
        assert "linear_input" not in kinds
        assert "lora_u" not in kinds

    def test_lorafa_saves_only_u(self):
        kinds = self._tape_kinds("lorafa_all", "ln")
        assert "linear_input" not in kinds
        assert kinds.count("lora_u") == 4  # q,k,v,proj adapters
