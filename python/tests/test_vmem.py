"""Structural L1 perf model invariants (EXPERIMENTS.md §Perf L1)."""

from compile import vmem
from compile.kernels import pallas_common as pc


def test_all_profiles_fit_vmem_budget():
    for p in vmem.report():
        assert p.vmem_bytes < vmem.VMEM_BUDGET, p


def test_tile_rows_power_of_two_and_bounded():
    for cols in (4, 64, 768, 3072, 13824):
        tr = pc.row_tile(100000, cols)
        assert tr >= 1
        assert tr & (tr - 1) == 0  # power of two
        assert tr * cols <= pc.VMEM_SLAB_ELEMS or tr == 1


def test_2bit_bwd_moves_less_dma_than_full():
    ours = vmem.profile_act_bwd(8192, 3072)
    base = vmem.profile_act_bwd_baseline(8192, 3072)
    ratio = base.dma_per_elem / ours.dma_per_elem
    assert 1.3 < ratio < 1.6, ratio  # ≈1.45× (12B vs 8.25B)


def test_msnorm_bwd_traffic_independent_of_affine():
    # MS-norm bwd reads z, σ, gy — no weight/bias traffic
    p = vmem.profile_msnorm_bwd(4096, 768)
    assert p.hbm_read_per_elem < 8.2
    assert p.hbm_write_per_elem == 4.0


def test_codes_bits_scale():
    p1 = vmem.profile_act_bwd(1024, 1024, codes_bits=2.0)
    p8 = vmem.profile_act_bwd(1024, 1024, codes_bits=8.0)
    assert p8.hbm_read_per_elem > p1.hbm_read_per_elem
