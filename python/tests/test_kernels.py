"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes per the coding-guide requirement; every
kernel must match ``ref.py`` to float32 tolerance.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (coeffs, msnorm, quant8, ref, regelu2, resilu2)

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, scale=3.0):
    return jnp.asarray(
        (np.random.RandomState(seed).randn(*shape) * scale).astype("float32"))


shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=17),   # rows
    st.sampled_from([4, 8, 12, 16, 64, 128]),  # cols (mult of 4 for packing)
)


class TestReGELU2:
    @settings(max_examples=20, deadline=None)
    @given(shape=shape_strategy, seed=st.integers(0, 2**16))
    def test_fwd_matches_gelu(self, shape, seed):
        x = _rand(shape, seed)
        y, _ = regelu2.fwd(x)
        np.testing.assert_allclose(y, ref.gelu(x), atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(shape=shape_strategy, seed=st.integers(0, 2**16))
    def test_bwd_matches_step_derivative(self, shape, seed):
        x = _rand(shape, seed)
        gy = _rand(shape, seed + 1)
        _, packed = regelu2.fwd(x)
        gx = regelu2.bwd(packed, gy)
        want = gy * ref.drelu_comb(x, coeffs.A_GELU, coeffs.C_GELU)
        np.testing.assert_allclose(gx, want, atol=1e-6)

    def test_codes_are_2bit(self):
        x = _rand((8, 64))
        _, packed = regelu2.fwd(x)
        assert packed.dtype == jnp.uint8
        assert packed.size == x.size // 4  # 2 bits/element

    def test_3d_input(self):
        x = _rand((2, 5, 16))
        y, packed = regelu2.fwd(x)
        np.testing.assert_allclose(y, ref.gelu(x), atol=1e-6)
        assert packed.shape == (2, 5, 4)


class TestReSiLU2:
    @settings(max_examples=20, deadline=None)
    @given(shape=shape_strategy, seed=st.integers(0, 2**16))
    def test_fwd_matches_silu(self, shape, seed):
        x = _rand(shape, seed)
        y, _ = resilu2.fwd(x)
        np.testing.assert_allclose(y, ref.silu(x), atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(shape=shape_strategy, seed=st.integers(0, 2**16))
    def test_bwd_matches_step_derivative(self, shape, seed):
        x = _rand(shape, seed, scale=8.0)  # exercise the wide silu tails
        gy = _rand(shape, seed + 1)
        _, packed = resilu2.fwd(x)
        gx = resilu2.bwd(packed, gy)
        want = gy * ref.drelu_comb(x, coeffs.A_SILU, coeffs.C_SILU)
        np.testing.assert_allclose(gx, want, atol=1e-6)


class TestMsNorm:
    @settings(max_examples=20, deadline=None)
    @given(shape=shape_strategy, seed=st.integers(0, 2**16))
    def test_msln(self, shape, seed):
        x = _rand(shape, seed)
        gy = _rand(shape, seed + 1)
        z, s = msnorm.msln_fwd(x)
        z2, s2 = ref.msln_fwd(x)
        np.testing.assert_allclose(z, z2, atol=1e-5)
        np.testing.assert_allclose(s, s2, atol=1e-6)
        np.testing.assert_allclose(
            msnorm.msln_bwd(z, s, gy), ref.msln_bwd(z2, s2, gy), atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(shape=shape_strategy, seed=st.integers(0, 2**16))
    def test_msrms(self, shape, seed):
        x = _rand(shape, seed)
        gy = _rand(shape, seed + 1)
        z, s = msnorm.msrms_fwd(x)
        z2, s2 = ref.msrms_fwd(x)
        np.testing.assert_allclose(z, z2, atol=1e-5)
        np.testing.assert_allclose(
            msnorm.msrms_bwd(z, s, gy), ref.msrms_bwd(z2, s2, gy), atol=1e-5)

    def test_msln_bwd_is_exact_ln_jacobian(self):
        """Algorithm 2 must equal jax.vjp of the (no-affine) LN forward."""
        x = _rand((6, 32), 3)
        gy = _rand((6, 32), 4)
        z, s = ref.msln_fwd(x)
        got = ref.msln_bwd(z, s, gy)
        f = lambda x: ref.msln_fwd(x)[0]
        _, vjp = jax.vjp(f, x)
        (want,) = vjp(gy)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_msrms_bwd_is_exact_rms_jacobian(self):
        x = _rand((6, 32), 5)
        gy = _rand((6, 32), 6)
        z, s = ref.msrms_fwd(x)
        got = ref.msrms_bwd(z, s, gy)
        f = lambda x: ref.msrms_fwd(x)[0]
        _, vjp = jax.vjp(f, x)
        (want,) = vjp(gy)
        np.testing.assert_allclose(got, want, atol=1e-5)


class TestQuant8:
    @settings(max_examples=20, deadline=None)
    @given(shape=shape_strategy, seed=st.integers(0, 2**16))
    def test_roundtrip_error_bounded(self, shape, seed):
        x = _rand(shape, seed)
        q, s = quant8.quant(x)
        xhat = quant8.dequant(q, s)
        # per-row symmetric int8: error <= scale/2 per element
        rows = np.asarray(x).reshape(-1, x.shape[-1])
        bound = np.abs(rows).max(-1, keepdims=True) / 127.0
        err = np.abs(np.asarray(xhat - x)).reshape(rows.shape)
        assert (err <= bound * 0.5 + 1e-7).all()


class TestPacking:
    @settings(max_examples=30, deadline=None)
    @given(n_bytes=st.integers(1, 64), seed=st.integers(0, 2**16))
    def test_pack2bit_roundtrip(self, n_bytes, seed):
        n = n_bytes * 4
        codes = jnp.asarray(
            np.random.RandomState(seed).randint(0, 4, n).astype("uint8"))
        packed = ref.pack2bit(codes)
        assert packed.size == n // 4
        np.testing.assert_array_equal(ref.unpack2bit(packed, n), codes)

    @settings(max_examples=30, deadline=None)
    @given(n_bytes=st.integers(1, 64), seed=st.integers(0, 2**16))
    def test_pack1bit_roundtrip(self, n_bytes, seed):
        n = n_bytes * 8
        bits = jnp.asarray(
            np.random.RandomState(seed).randint(0, 2, n).astype("uint8"))
        packed = ref.pack1bit(bits)
        assert packed.size == n // 8
        np.testing.assert_array_equal(ref.unpack1bit(packed, n), bits)


class TestApproxTheory:
    """Sanity checks on the paper's functional-closeness claims (§4.2)."""

    def test_relu_comb_limiting_behavior(self):
        # Prop 4.3: h̃ → h at ±∞
        for name, h in (("regelu2", ref.gelu), ("resilu2", ref.silu)):
            a, c = coeffs.BY_NAME[name]
            for x in (-50.0, 50.0):
                xx = jnp.asarray([x], dtype=jnp.float32)
                diff = float(jnp.abs(h(xx) - ref.relu_comb(xx, a, c))[0])
                assert diff < 1e-4, (name, x, diff)

    def test_constraint_eq13(self):
        # sum a_i c_i + (1 - sum a_i) c_3 ≈ 0 (zero-intercept constraint)
        for name in ("regelu2", "resilu2"):
            (a1, a2), (c1, c2, c3) = coeffs.BY_NAME[name]
            val = a1 * c1 + a2 * c2 + (1 - a1 - a2) * c3
            assert abs(val) < 2e-2, (name, val)

    def test_l2_objective_is_small(self):
        # ∫(h − h̃)² over [-8, 8] at the paper's optima: ≈9.5e-3 for GELU,
        # ≈4.0e-2 for SiLU (wider transition region). A 3-ReLU fit cannot
        # do fundamentally better — see rust coeffs solver (`exp appe`).
        xs = jnp.linspace(-8, 8, 20001)
        for name, h, bound in (("regelu2", ref.gelu, 0.011),
                               ("resilu2", ref.silu, 0.045)):
            a, c = coeffs.BY_NAME[name]
            d = h(xs) - ref.relu_comb(xs, a, c)
            l2 = float(jnp.trapezoid(d * d, xs))
            assert l2 < bound, (name, l2)

    def test_paper_coeffs_beat_perturbations(self):
        # local optimality: nudging any coefficient worsens the objective
        xs = jnp.linspace(-8, 8, 8001)

        def obj(a, c, h):
            d = h(xs) - ref.relu_comb(xs, a, c)
            return float(jnp.trapezoid(d * d, xs))

        for name, h in (("regelu2", ref.gelu), ("resilu2", ref.silu)):
            a, c = coeffs.BY_NAME[name]
            base = obj(a, c, h)
            for i in range(2):
                for eps in (-0.05, 0.05):
                    aa = list(a); aa[i] += eps
                    assert obj(tuple(aa), c, h) > base
