"""Full-model gradient checks, residual accounting, CKPT equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.models import Model, ModelCfg, surrogate
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TINY_VIT = dict(arch="vit", dim=32, depth=2, n_heads=2, n_tokens=8,
                patch_dim=12, batch=4)
TINY_LLAMA = dict(arch="llama", dim=32, depth=2, n_heads=2, n_tokens=8,
                  vocab=50, batch=4)
TINY_ROB = dict(arch="roberta", dim=32, depth=2, n_heads=2, n_tokens=8,
                vocab=50, n_classes=4, batch=4)


def make_batch(cfg, seed=1):
    rng = np.random.RandomState(seed)
    if cfg.arch == "vit":
        x = jnp.asarray(
            rng.randn(cfg.batch, cfg.n_tokens, cfg.patch_dim).astype("f4"))
        y = jnp.asarray(rng.randint(0, cfg.n_classes, cfg.batch), jnp.int32)
    elif cfg.arch == "roberta":
        x = jnp.asarray(rng.randint(0, cfg.vocab, (cfg.batch, cfg.n_tokens)),
                        jnp.int32)
        y = jnp.asarray(rng.randint(0, cfg.n_classes, cfg.batch), jnp.int32)
    else:
        x = jnp.asarray(rng.randint(0, cfg.vocab, (cfg.batch, cfg.n_tokens)),
                        jnp.int32)
        y = jnp.asarray(rng.randint(0, cfg.vocab, (cfg.batch, cfg.n_tokens)),
                        jnp.int32)
    return x, y


def run_manual(m, P, x, y):
    out = m.fwd(P, x, y)
    loss, metric, res = out[0], out[1], list(out[2:])
    grads = m.bwd(P, res, x, y)
    return loss, metric, res, grads


def autodiff_grads(m, P, x, y):
    def loss_fn(tp):
        P2 = list(P)
        for i, idx in enumerate(m.trainable_idx):
            P2[idx] = tp[i]
        return m.loss_ref(P2, x, y)

    return jax.grad(loss_fn)([P[i] for i in m.trainable_idx])


EXACT_CASES = [
    dict(**TINY_VIT, tuning="full", activation="gelu", norm="ln"),
    dict(**TINY_VIT, tuning="lora_qv", activation="gelu", norm="ln"),
    dict(**TINY_VIT, tuning="lora_qv", activation="gelu", norm="msln"),
    dict(**TINY_VIT, tuning="lora_all", activation="mesa_gelu8", norm="msln"),
    dict(**TINY_VIT, tuning="lorafa_qv", activation="relu", norm="ln"),
    dict(**TINY_LLAMA, tuning="lora_all", activation="silu", norm="msrms"),
    dict(**TINY_LLAMA, tuning="full", activation="silu", norm="rms"),
    dict(**TINY_LLAMA, tuning="lorafa_all", activation="silu", norm="rms"),
    dict(**TINY_ROB, tuning="lora_all", activation="gelu", norm="msln"),
]


@pytest.mark.parametrize("case", EXACT_CASES, ids=lambda c: "-".join(
    str(c[k]) for k in ("arch", "tuning", "activation", "norm")))
def test_manual_bwd_matches_autodiff(case):
    cfg = ModelCfg(**case)
    m = Model(cfg)
    P = [jnp.asarray(p) for p in m.init_params(0)]
    x, y = make_batch(cfg)
    loss, metric, res, grads = run_manual(m, P, x, y)
    want = autodiff_grads(m, P, x, y)
    tol = 2e-3 if cfg.activation == "mesa_gelu8" else 2e-4
    for g, w, idx in zip(grads, want, m.trainable_idx):
        np.testing.assert_allclose(
            g, w, atol=tol, err_msg=m.param_specs[idx].name)


@pytest.mark.parametrize("case", [
    dict(**TINY_VIT, tuning="lora_all", activation="regelu2", norm="msln"),
    dict(**TINY_VIT, tuning="lora_qv", activation="regelu2d", norm="ln"),
    dict(**TINY_LLAMA, tuning="lora_all", activation="resilu2", norm="msrms"),
], ids=lambda c: c["activation"])
def test_approxbp_matches_surrogate_autodiff(case):
    """Manual bwd of the surrogate model == jax.grad of the surrogate."""
    cfg = ModelCfg(**case)
    sm = surrogate(cfg)
    P = [jnp.asarray(p) for p in sm.init_params(0)]
    x, y = make_batch(cfg)
    loss, metric, res, grads = run_manual(sm, P, x, y)
    want = autodiff_grads(sm, P, x, y)
    for g, w, idx in zip(grads, want, sm.trainable_idx):
        np.testing.assert_allclose(
            g, w, atol=2e-4, err_msg=sm.param_specs[idx].name)


def test_approx_forward_is_exact():
    """Appendix C: the ReGELU2 model's FORWARD equals the GELU model's."""
    base = ModelCfg(**TINY_VIT, tuning="lora_qv", activation="gelu",
                    norm="ln")
    alt = ModelCfg(**TINY_VIT, tuning="lora_qv", activation="regelu2",
                   norm="ln")
    m1, m2 = Model(base), Model(alt)
    P = [jnp.asarray(p) for p in m1.init_params(0)]
    x, y = make_batch(base)
    l1 = m1.fwd(P, x, y)[0]
    l2 = m2.fwd(P, x, y)[0]
    np.testing.assert_allclose(l1, l2, atol=1e-6)


def test_surrogate_forward_differs():
    """Appendix C flip-side: substituting the forward DOES change outputs."""
    cfg = ModelCfg(**TINY_VIT, tuning="lora_qv", activation="regelu2",
                   norm="ln")
    m, sm = Model(cfg), surrogate(cfg)
    P = [jnp.asarray(p) for p in m.init_params(0)]
    x, y = make_batch(cfg)
    assert abs(float(m.fwd(P, x, y)[0]) - float(sm.fwd(P, x, y)[0])) > 1e-6


class TestResidualAccounting:
    def _bytes(self, cfg):
        m = Model(ModelCfg(**cfg))
        P = [jnp.asarray(p) for p in m.init_params(0)]
        x, y = make_batch(m.cfg)
        m.fwd(P, x, y)
        return sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
                   for s in m.tape_specs), m

    def test_regelu2_saves_less_than_gelu(self):
        b_gelu, _ = self._bytes(dict(**TINY_VIT, tuning="lora_qv",
                                     activation="gelu", norm="ln"))
        b_re, _ = self._bytes(dict(**TINY_VIT, tuning="lora_qv",
                                   activation="regelu2", norm="ln"))
        assert b_re < b_gelu

    def test_msln_saves_less_than_ln_when_linears_adapted(self):
        b_ln, _ = self._bytes(dict(**TINY_VIT, tuning="lora_all",
                                   activation="gelu", norm="ln"))
        b_ms, _ = self._bytes(dict(**TINY_VIT, tuning="lora_all",
                                   activation="gelu", norm="msln"))
        assert b_ms < b_ln

    def test_combined_saving_ordering(self):
        """(ReGELU2, MS-LN) < each single change < baseline — Table 1."""
        base, _ = self._bytes(dict(**TINY_VIT, tuning="lora_all",
                                   activation="gelu", norm="ln"))
        only_act, _ = self._bytes(dict(**TINY_VIT, tuning="lora_all",
                                       activation="regelu2", norm="ln"))
        only_norm, _ = self._bytes(dict(**TINY_VIT, tuning="lora_all",
                                        activation="gelu", norm="msln"))
        both, _ = self._bytes(dict(**TINY_VIT, tuning="lora_all",
                                   activation="regelu2", norm="msln"))
        assert both < only_act < base
        assert both < only_norm < base

    def test_ckpt_saves_least_memory(self):
        b_ckpt, _ = self._bytes(dict(**TINY_VIT, tuning="lora_qv",
                                     activation="gelu", norm="ln", ckpt=True))
        b_base, _ = self._bytes(dict(**TINY_VIT, tuning="lora_qv",
                                     activation="gelu", norm="ln"))
        assert b_ckpt < b_base

    def test_lorafa_norm_sharing_is_moot(self):
        """LoRA-FA: condition 3 of Prop 5.1 fails → MS-LN saves ~nothing
        beyond what plain LN does (both store exactly one [B,N,C])."""
        b_ln, m1 = self._bytes(dict(**TINY_VIT, tuning="lorafa_all",
                                    activation="gelu", norm="ln"))
        b_ms, m2 = self._bytes(dict(**TINY_VIT, tuning="lorafa_all",
                                    activation="gelu", norm="msln"))
        # MS-LN still avoids the separate mu tensor, but must NOT get the
        # big shared-z win it gets with lora_all
        big = lambda m: sum(
            int(np.prod(s.shape)) * 4 for s in m.tape_specs
            if s.kind in ("norm_input", "norm_shared", "linear_input"))
        assert big(m2) == big(m1)


def test_ckpt_grads_equal_plain_grads():
    cfg_p = ModelCfg(**TINY_VIT, tuning="lora_qv", activation="gelu",
                     norm="ln")
    cfg_c = ModelCfg(**TINY_VIT, tuning="lora_qv", activation="gelu",
                     norm="ln", ckpt=True)
    mp, mc = Model(cfg_p), Model(cfg_c)
    P = [jnp.asarray(p) for p in mp.init_params(0)]
    x, y = make_batch(cfg_p)
    _, _, _, gp = run_manual(mp, P, x, y)
    _, _, _, gc = run_manual(mc, P, x, y)
    for a, b in zip(gp, gc):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_pallas_path_matches_jnp_path():
    """Composition proof: use_pallas=True gives identical loss and grads."""
    base = dict(**TINY_VIT, tuning="lora_qv", activation="regelu2",
                norm="msln")
    m1 = Model(ModelCfg(**base))
    m2 = Model(ModelCfg(**base, use_pallas=True))
    P = [jnp.asarray(p) for p in m1.init_params(0)]
    x, y = make_batch(m1.cfg)
    l1, _, r1, g1 = run_manual(m1, P, x, y)
    l2, _, r2, g2 = run_manual(m2, P, x, y)
    np.testing.assert_allclose(l1, l2, atol=1e-5)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_few_steps_of_sgd_reduce_loss():
    """The whole manual-backprop stack actually trains."""
    cfg = ModelCfg(**TINY_VIT, tuning="lora_all", activation="regelu2",
                   norm="msln")
    m = Model(cfg)
    P = [jnp.asarray(p) for p in m.init_params(0)]
    x, y = make_batch(cfg)
    first = None
    for step in range(30):
        out = m.fwd(P, x, y)
        loss, res = out[0], list(out[2:])
        if first is None:
            first = float(loss)
        grads = m.bwd(P, res, x, y)
        for gi, idx in enumerate(m.trainable_idx):
            P[idx] = P[idx] - 0.05 * grads[gi]
    assert float(loss) < first * 0.8, (first, float(loss))
