"""AOT ABI tests: manifest consistency + HLO round-trip executability.

The HLO text written by aot.py is compiled back through the jax CPU client
and executed against the eager model — proving what rust will load computes
exactly what L2 defines.
"""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile.models import Model
from compile.presets import PRESETS

jax.config.update("jax_platform_name", "cpu")

SMALL = "vitt_loraqv_regelu2_msln"


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.export(SMALL, out)
    return out, manifest


def test_manifest_schema(exported):
    _, m = exported
    assert m["preset"] == SMALL
    assert m["config"]["activation"] == "regelu2"
    assert m["config"]["norm"] == "msln"
    for p in m["params"]:
        assert p["name"] and p["shape"]
    for r in m["residuals"]:
        assert r["bytes"] == int(np.prod(r["shape"])) * np.dtype(
            {"float32": "f4", "uint8": "u1", "int8": "i1",
             "int32": "i4"}[r["dtype"]]).itemsize
    assert m["residual_bytes_total"] == sum(
        r["bytes"] for r in m["residuals"])


def test_params_bin_size(exported):
    out, m = exported
    want = sum(int(np.prod(p["shape"])) * 4 for p in m["params"])
    got = os.path.getsize(os.path.join(out, SMALL, "params.bin"))
    assert got == want


def test_merge_map_covers_all_norms(exported):
    _, m = exported
    cfg = m["config"]
    # depth blocks × (attn + mlp) + head norm
    assert len(m["merges"]) == cfg["depth"] * 2 + 1


def test_codes_residuals_present(exported):
    _, m = exported
    kinds = {r["kind"] for r in m["residuals"]}
    assert "act_codes" in kinds       # ReGELU2 2-bit codes
    assert "norm_shared" in kinds     # MS-LN shared z
    assert "act_full" not in kinds    # no full activation tensors
    assert "norm_input" not in kinds  # no norm inputs saved


def test_hlo_text_parses_and_arity_matches(exported):
    """The HLO text must parse back, and its parameter/output arity must
    match the manifest ABI (params…, x, y) -> (loss, metric, residual…).

    Full numeric round-trip (PJRT compile + execute + compare against the
    selfcheck batch) happens on the rust side: rust/tests/e2e_runtime.rs.
    """
    out, m = exported
    for which in ("fwd", "bwd"):
        with open(os.path.join(out, SMALL, f"{which}.hlo.txt")) as f:
            txt = f.read()
        mod = xc._xla.hlo_module_from_text(txt)  # raises on parse error
        assert mod is not None
        n_entry_params = txt.count("ENTRY")
        assert n_entry_params == 1
    n_params = len(m["params"])
    n_res = len(m["residuals"])
    with open(os.path.join(out, SMALL, "fwd.hlo.txt")) as f:
        fwd_txt = f.read()
    # entry computation declares one parameter per ABI input
    import re

    entry = fwd_txt[fwd_txt.index("ENTRY"):]
    params_in_entry = len(re.findall(r"parameter\(\d+\)", entry))
    assert params_in_entry == n_params + 2  # + x + y


def test_selfcheck_written(exported):
    out, m = exported
    sc = m["selfcheck"]
    assert np.isfinite(sc["loss"]) and np.isfinite(sc["metric"])
    n_train = sum(1 for p in m["params"] if p["trainable"])
    assert len(sc["grad_l2"]) == n_train
    for fn in ("selfcheck_x.bin", "selfcheck_y.bin", "selfcheck_grads.bin"):
        assert os.path.getsize(os.path.join(out, SMALL, fn)) > 0


def test_all_presets_instantiate():
    """Every preset builds a Model and a consistent trainable set."""
    for name, cfg in PRESETS.items():
        m = Model(cfg)
        assert m.trainable_idx, name
        names = [s.name for s in m.param_specs]
        assert len(names) == len(set(names)), f"dup param names in {name}"
