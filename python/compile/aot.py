"""AOT exporter: lower each preset's fwd/bwd to HLO **text** + manifest.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Per preset, writes to ``artifacts/<preset>/``:
  fwd.hlo.txt    (params…, x, y) -> (loss, metric, residual…)
  bwd.hlo.txt    (params…, residual…, x, y) -> (grad…  for trainables)
  params.bin     f32-LE initial parameters, concatenated in manifest order
  manifest.json  the full ABI: params, batch, residuals (+bytes), merges

Usage:  python -m compile.aot --out ../artifacts [preset …|--default|--all]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .models import Model
from .presets import DEFAULT, PRESETS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(name: str, outdir: str) -> dict:
    cfg = PRESETS[name]
    model = Model(cfg)
    params0 = model.init_params(seed=0)
    pspecs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params0]
    x_spec, y_spec = model.batch_spec()
    n_params = len(pspecs)

    def fwd_flat(*args):
        P = list(args[:n_params])
        x, y = args[n_params], args[n_params + 1]
        return model.fwd(P, x, y)

    # trace fwd first: records tape indices on the layer objects and
    # tape specs on the model (needed before bwd can be traced).
    # keep_unused=True: the ABI promises one HLO parameter per manifest
    # entry even when XLA would dead-code-eliminate an unused input
    # (e.g. frozen embeddings in bwd).
    fwd_lowered = jax.jit(fwd_flat, keep_unused=True).lower(
        *pspecs, x_spec, y_spec)
    res_specs = model.tape_specs
    res_shape_dtype = [
        jax.ShapeDtypeStruct(s.shape, np.dtype(s.dtype)) for s in res_specs
    ]

    def bwd_flat(*args):
        P = list(args[:n_params])
        res = list(args[n_params:n_params + len(res_specs)])
        x = args[n_params + len(res_specs)]
        y = args[n_params + len(res_specs) + 1]
        return model.bwd(P, res, x, y)

    bwd_lowered = jax.jit(bwd_flat, keep_unused=True).lower(
        *pspecs, *res_shape_dtype, x_spec, y_spec)

    d = os.path.join(outdir, name)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, "fwd.hlo.txt"), "w") as f:
        f.write(to_hlo_text(fwd_lowered))
    with open(os.path.join(d, "bwd.hlo.txt"), "w") as f:
        f.write(to_hlo_text(bwd_lowered))
    with open(os.path.join(d, "params.bin"), "wb") as f:
        for p in params0:
            f.write(np.ascontiguousarray(p, dtype=np.float32).tobytes())

    # ---- selfcheck: deterministic batch + eager expected outputs --------
    # The rust e2e test (rust/tests/e2e_runtime.rs) loads these, runs the
    # compiled fwd/bwd through PJRT, and asserts numeric agreement: the
    # cross-language proof that all three layers compose.
    rng = np.random.RandomState(42)
    if cfg.arch == "vit":
        x = (rng.randn(*x_spec.shape) * 1.0).astype(np.float32)
        y = rng.randint(0, cfg.n_classes, y_spec.shape).astype(np.int32)
    else:
        x = rng.randint(0, cfg.vocab, x_spec.shape).astype(np.int32)
        hi = cfg.vocab if cfg.arch == "llama" else cfg.n_classes
        y = rng.randint(0, hi, y_spec.shape).astype(np.int32)
    P = [jnp.asarray(p) for p in params0]
    eager = model.fwd(P, jnp.asarray(x), jnp.asarray(y))
    loss, metric, res = eager[0], eager[1], list(eager[2:])
    grads = model.bwd(P, res, jnp.asarray(x), jnp.asarray(y))
    with open(os.path.join(d, "selfcheck_x.bin"), "wb") as f:
        f.write(x.tobytes())
    with open(os.path.join(d, "selfcheck_y.bin"), "wb") as f:
        f.write(y.tobytes())
    with open(os.path.join(d, "selfcheck_grads.bin"), "wb") as f:
        for g in grads:
            f.write(np.ascontiguousarray(g, dtype=np.float32).tobytes())
    selfcheck = {
        "loss": float(loss),
        "metric": float(metric),
        "grad_l2": [float(jnp.sqrt(jnp.sum(g * g))) for g in grads],
    }

    def nbytes(spec):
        return int(np.prod(spec.shape)) * np.dtype(spec.dtype).itemsize

    manifest = {
        "preset": name,
        "config": {k: getattr(cfg, k) for k in (
            "arch", "dim", "depth", "n_heads", "mlp_ratio", "n_tokens",
            "patch_dim", "n_classes", "vocab", "tuning", "activation",
            "norm", "lora_rank", "use_pallas", "batch", "ckpt")},
        "params": [
            {"name": s.name, "shape": list(s.shape),
             "trainable": bool(s.trainable)}
            for s in model.param_specs
        ],
        "batch": {
            "x": {"shape": list(x_spec.shape), "dtype": x_spec.dtype.name},
            "y": {"shape": list(y_spec.shape), "dtype": y_spec.dtype.name},
        },
        "residuals": [
            {"name": s.name, "kind": s.kind, "module": s.module,
             "shape": list(s.shape), "dtype": s.dtype,
             "bits_per_elem": s.bits_per_logical_elem,
             "bytes": nbytes(s)}
            for s in res_specs
        ],
        "residual_bytes_total": sum(nbytes(s) for s in res_specs),
        "merges": model.merge_map(),
        "selfcheck": selfcheck,
        "files": {"fwd": "fwd.hlo.txt", "bwd": "bwd.hlo.txt",
                  "params": "params.bin"},
    }
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("presets", nargs="*")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--default", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    names = list(args.presets)
    if args.default or (not names and not args.all):
        names += [n for n in DEFAULT if n not in names]
    if args.all:
        names = list(PRESETS)
    for n in names:
        if n not in PRESETS:
            sys.exit(f"unknown preset {n!r}; known: {sorted(PRESETS)}")
        m = export(n, args.out)
        print(f"{n}: params={len(m['params'])} residuals="
              f"{len(m['residuals'])} "
              f"res_bytes={m['residual_bytes_total']:,}")


if __name__ == "__main__":
    main()
