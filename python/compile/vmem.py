"""L1 structural performance model: VMEM residency + HBM↔VMEM traffic.

Under interpret=True there is no meaningful TPU wallclock, so the L1 perf
deliverable is structural (DESIGN.md §6): for each kernel and shape this
module reports the VMEM slab footprint chosen by ``pallas_common.row_tile``
and the DMA bytes per element moved in each direction — the quantities the
EXPERIMENTS.md §Perf L1 roofline argument is built on.

Run as a script for the report:  python -m compile.vmem
"""

import dataclasses

from .kernels import pallas_common as pc


@dataclasses.dataclass
class KernelProfile:
    name: str
    rows: int
    cols: int
    tile_rows: int
    vmem_bytes: int          # resident slab bytes (all operands)
    hbm_read_per_elem: float
    hbm_write_per_elem: float

    @property
    def dma_per_elem(self):
        return self.hbm_read_per_elem + self.hbm_write_per_elem


def profile_act_fwd(rows, cols, codes_bits=2.0):
    """ReGELU2/ReSiLU2 fused fwd+encode: read x, write y + packed codes."""
    tr = pc.row_tile(rows, cols)
    return KernelProfile(
        name="act_fwd_encode",
        rows=rows, cols=cols, tile_rows=tr,
        vmem_bytes=tr * cols * 4 * 2 + tr * (cols // 4),
        hbm_read_per_elem=4.0,
        hbm_write_per_elem=4.0 + codes_bits / 8.0,
    )


def profile_act_bwd(rows, cols, codes_bits=2.0):
    """Decode-bwd: read packed + gy, write gx (no dequant pass)."""
    tr = pc.row_tile(rows, cols)
    return KernelProfile(
        name="act_bwd_decode",
        rows=rows, cols=cols, tile_rows=tr,
        vmem_bytes=tr * cols * 4 * 2 + tr * (cols // 4),
        hbm_read_per_elem=4.0 + codes_bits / 8.0,
        hbm_write_per_elem=4.0,
    )


def profile_act_bwd_baseline(rows, cols):
    """GELU baseline bwd: read full x + gy, write gx."""
    tr = pc.row_tile(rows, cols)
    return KernelProfile(
        name="act_bwd_full(gelu)",
        rows=rows, cols=cols, tile_rows=tr,
        vmem_bytes=tr * cols * 4 * 3,
        hbm_read_per_elem=8.0,
        hbm_write_per_elem=4.0,
    )


def profile_msnorm_fwd(rows, cols):
    tr = pc.row_tile(rows, cols)
    return KernelProfile(
        name="msnorm_fwd",
        rows=rows, cols=cols, tile_rows=tr,
        vmem_bytes=tr * cols * 4 * 2 + tr * 4,
        hbm_read_per_elem=4.0,
        hbm_write_per_elem=4.0 + 4.0 / cols,
    )


def profile_msnorm_bwd(rows, cols):
    tr = pc.row_tile(rows, cols)
    return KernelProfile(
        name="msnorm_bwd",
        rows=rows, cols=cols, tile_rows=tr,
        vmem_bytes=tr * cols * 4 * 3 + tr * 4,
        hbm_read_per_elem=8.0 + 4.0 / cols,
        hbm_write_per_elem=4.0,
    )


VMEM_BUDGET = 16 << 20  # ~16 MiB/core on contemporary TPUs


def report(rows=8192, cols_list=(512, 768, 3072, 13824)):
    out = []
    for cols in cols_list:
        for prof in (
            profile_act_fwd(rows, cols),
            profile_act_bwd(rows, cols),
            profile_act_bwd_baseline(rows, cols),
            profile_msnorm_fwd(rows, cols),
            profile_msnorm_bwd(rows, cols),
        ):
            out.append(prof)
    return out


def main():
    print(f"{'kernel':<22} {'cols':>6} {'TR':>5} {'VMEM KiB':>9} "
          f"{'rd B/el':>8} {'wr B/el':>8} {'fits':>5}")
    for p in report():
        print(f"{p.name:<22} {p.cols:>6} {p.tile_rows:>5} "
              f"{p.vmem_bytes / 1024:>9.1f} {p.hbm_read_per_elem:>8.2f} "
              f"{p.hbm_write_per_elem:>8.2f} "
              f"{'ok' if p.vmem_bytes < VMEM_BUDGET else 'NO':>5}")
    base = profile_act_bwd_baseline(8192, 3072)
    ours = profile_act_bwd(8192, 3072)
    print(f"\nactivation bwd DMA reduction (ours vs full-tensor): "
          f"{base.dma_per_elem / ours.dma_per_elem:.2f}x")


if __name__ == "__main__":
    main()
