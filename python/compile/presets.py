"""Named model presets → artifacts/<preset>/{fwd,bwd}.hlo.txt.

Families:
  vitt_*    — ViT-tiny-style   (the measured substrate for Tables 1/2/6/7,
              Figures 1/4; the analytical memmodel extrapolates to ViT-B/L)
  llama_*   — LLaMA-style decoder (Tables 3/8/9, Figure 6)
  rob_*     — RoBERTa-style encoder (Table 4)
  e2e_*     — the end-to-end example models (bigger, jnp path for speed)
  pallas_*  — same math lowered through the Pallas kernels (composition
              proof; used by rust e2e_runtime tests)
"""

from .models import ModelCfg

VIT_T = dict(arch="vit", dim=128, depth=4, n_heads=4, n_tokens=64,
             patch_dim=48, n_classes=10, batch=16)
LLAMA_T = dict(arch="llama", dim=128, depth=4, n_heads=4, n_tokens=128,
               vocab=512, batch=4, mlp_ratio=2.7)
ROB_T = dict(arch="roberta", dim=128, depth=4, n_heads=4, n_tokens=64,
             vocab=512, n_classes=4, batch=16)

# End-to-end driver models (examples/). Sized for the 1-core CPU testbed:
# ~2.7M params ≈ 1-2 s/step so a few-hundred-step fine-tune stays practical;
# the paper-scale (ViT-B/L, LLaMA-7B/13B) numbers come from the analytical
# memmodel (DESIGN.md §3 substitution table).
VIT_E2E = dict(arch="vit", dim=192, depth=6, n_heads=6, n_tokens=64,
               patch_dim=48, n_classes=10, batch=8)
LLAMA_E2E = dict(arch="llama", dim=192, depth=4, n_heads=6, n_tokens=128,
                 vocab=512, batch=2, mlp_ratio=2.7)


def _mk(base, **kw):
    d = dict(base)
    d.update(kw)
    return ModelCfg(**d)


PRESETS = {}


def _reg(name, base, **kw):
    PRESETS[name] = _mk(base, **kw)


# --- Table 1 / Figure 1 / Figure 4: ViT + LoRA/LoRA-FA -------------------
for tun, tag in (("lora_qv", "loraqv"), ("lora_all", "loraall")):
    _reg(f"vitt_{tag}_gelu_ln", VIT_T, tuning=tun, activation="gelu", norm="ln")
    _reg(f"vitt_{tag}_mesa_ln", VIT_T, tuning=tun, activation="mesa_gelu8", norm="ln")
    _reg(f"vitt_{tag}_regelu2_ln", VIT_T, tuning=tun, activation="regelu2", norm="ln")
    _reg(f"vitt_{tag}_gelu_mesaln", VIT_T, tuning=tun, activation="gelu", norm="mesa_ln8")
    _reg(f"vitt_{tag}_gelu_msln", VIT_T, tuning=tun, activation="gelu", norm="msln")
    _reg(f"vitt_{tag}_mesa_mesaln", VIT_T, tuning=tun, activation="mesa_gelu8", norm="mesa_ln8")
    _reg(f"vitt_{tag}_regelu2_msln", VIT_T, tuning=tun, activation="regelu2", norm="msln")
    _reg(f"vitt_{tag}_relu_ln", VIT_T, tuning=tun, activation="relu", norm="ln")
for tag, tun in (("lorafaqv", "lorafa_qv"), ("lorafaall", "lorafa_all")):
    _reg(f"vitt_{tag}_gelu_ln", VIT_T, tuning=tun, activation="gelu", norm="ln")
    _reg(f"vitt_{tag}_mesa_ln", VIT_T, tuning=tun, activation="mesa_gelu8", norm="ln")
    _reg(f"vitt_{tag}_mesa_mesaln", VIT_T, tuning=tun, activation="mesa_gelu8", norm="mesa_ln8")
    _reg(f"vitt_{tag}_regelu2_ln", VIT_T, tuning=tun, activation="regelu2", norm="ln")

# CKPT baseline (Fig 1)
_reg("vitt_loraqv_gelu_ln_ckpt", VIT_T, tuning="lora_qv", activation="gelu",
     norm="ln", ckpt=True)

# --- Table 2: full tuning --------------------------------------------------
for act, nrm in (("gelu", "ln"), ("regelu2", "ln"), ("gelu", "msln"),
                 ("regelu2", "msln")):
    _reg(f"vitt_full_{act}_{nrm}", VIT_T, tuning="full", activation=act,
         norm=nrm)

# --- Table 6 / Appendix I: ReGELU2-d ablation ------------------------------
_reg("vitt_loraqv_regelu2d_ln", VIT_T, tuning="lora_qv",
     activation="regelu2d", norm="ln")
_reg("vitt_loraall_regelu2d_ln", VIT_T, tuning="lora_all",
     activation="regelu2d", norm="ln")

# --- Table 3/8/9: LLaMA-style QLoRA-sim ------------------------------------
for act, nrm in (("silu", "rms"), ("resilu2", "rms"), ("silu", "msrms"),
                 ("resilu2", "msrms")):
    _reg(f"llama_loraall_{act}_{nrm}", LLAMA_T, tuning="lora_all",
         activation=act, norm=nrm)

# --- Table 4: RoBERTa-style ------------------------------------------------
for act, nrm in (("gelu", "ln"), ("regelu2", "ln"), ("gelu", "msln"),
                 ("regelu2", "msln")):
    _reg(f"rob_loraall_{act}_{nrm}", ROB_T, tuning="lora_all",
         activation=act, norm=nrm)

# --- Appendix C: substituting the forward pass degrades the model ---------
# (handled in-test via models.surrogate; no artifact needed)

# --- end-to-end drivers ----------------------------------------------------
_reg("e2e_vit_pretrain", VIT_E2E, tuning="full", activation="gelu", norm="ln")
_reg("e2e_vit_gelu_ln", VIT_E2E, tuning="lora_qv", activation="gelu", norm="ln")
_reg("e2e_vit_regelu2_msln", VIT_E2E, tuning="lora_qv",
     activation="regelu2", norm="msln")
_reg("e2e_llama_silu_rms", LLAMA_E2E, tuning="lora_all", activation="silu",
     norm="rms")
_reg("e2e_llama_resilu2_msrms", LLAMA_E2E, tuning="lora_all",
     activation="resilu2", norm="msrms")

# --- pallas-lowered composition proof --------------------------------------
_reg("pallas_vit_regelu2_msln", VIT_T, tuning="lora_qv",
     activation="regelu2", norm="msln", use_pallas=True, batch=4)
_reg("pallas_llama_resilu2_msrms", LLAMA_T, tuning="lora_all",
     activation="resilu2", norm="msrms", use_pallas=True, batch=2)

# the standard set `make artifacts` builds (examples+tests need these);
# benches build the rest on demand via `ambp compile` -> aot.py
DEFAULT = [
    "vitt_loraqv_gelu_ln", "vitt_loraqv_regelu2_msln",
    "vitt_loraqv_gelu_msln", "vitt_loraqv_mesa_mesaln",
    "vitt_loraqv_gelu_ln_ckpt",
    "llama_loraall_silu_rms", "llama_loraall_resilu2_msrms",
    "e2e_vit_pretrain", "e2e_vit_gelu_ln", "e2e_vit_regelu2_msln",
    "e2e_llama_silu_rms", "e2e_llama_resilu2_msrms",
    "pallas_vit_regelu2_msln",
]
