"""Memory-sharing normalization Pallas kernels (paper §5, Algorithms 2–3).

MS-LN / MS-RMSNorm forward emits (z, σ); backward consumes (z, σ, gy) —
z is *shared* with the following linear layer's saved input, so the norm's
own incremental residual is just the per-row σ.  One row-slab stays
resident in VMEM per grid step; σ is a VPU rowwise reduction.
"""

import jax
import jax.numpy as jnp

from . import pallas_common as pc


def _msln_fwd_kernel(eps):
    def kernel(x_ref, z_ref, sigma_ref):
        x = x_ref[...]
        mu = jnp.mean(x, axis=-1, keepdims=True)
        xc = x - mu
        sigma = jnp.sqrt(jnp.mean(xc * xc, axis=-1, keepdims=True) + eps)
        z_ref[...] = xc / sigma
        sigma_ref[...] = sigma

    return kernel


def _msln_bwd_kernel(z_ref, sigma_ref, gy_ref, gx_ref):
    z, sigma, gy = z_ref[...], sigma_ref[...], gy_ref[...]
    hg = gy - jnp.mean(gy, axis=-1, keepdims=True)
    zg = jnp.mean(z * gy, axis=-1, keepdims=True)
    gx_ref[...] = (hg - z * zg) / sigma


def _msrms_fwd_kernel(eps):
    def kernel(x_ref, z_ref, sigma_ref):
        x = x_ref[...]
        sigma = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
        z_ref[...] = x / sigma
        sigma_ref[...] = sigma

    return kernel


def _msrms_bwd_kernel(z_ref, sigma_ref, gy_ref, gx_ref):
    z, sigma, gy = z_ref[...], sigma_ref[...], gy_ref[...]
    zg = jnp.mean(z * gy, axis=-1, keepdims=True)
    gx_ref[...] = (gy - z * zg) / sigma


def msln_fwd(x, eps=1e-6):
    """Returns (z, sigma); sigma has shape [..., 1]."""
    x2 = pc.as2d(x)
    z, sigma = pc.run_rowwise(
        _msln_fwd_kernel(eps), x2, out_shapes=[(x2.shape[1], x.dtype), (1, x.dtype)]
    )
    return z.reshape(x.shape), sigma.reshape(*x.shape[:-1], 1)


def msln_bwd(z, sigma, gy):
    z2, s2, g2 = pc.as2d(z), pc.as2d(sigma), pc.as2d(gy)
    (gx,) = pc.run_rowwise(
        _msln_bwd_kernel, z2, out_shapes=[(z2.shape[1], z.dtype)],
        extra_inputs=(s2, g2),
    )
    return gx.reshape(z.shape)


def msrms_fwd(x, eps=1e-6):
    """Returns (z, sigma); sigma has shape [..., 1]."""
    x2 = pc.as2d(x)
    z, sigma = pc.run_rowwise(
        _msrms_fwd_kernel(eps), x2, out_shapes=[(x2.shape[1], x.dtype), (1, x.dtype)]
    )
    return z.reshape(x.shape), sigma.reshape(*x.shape[:-1], 1)


def msrms_bwd(z, sigma, gy):
    z2, s2, g2 = pc.as2d(z), pc.as2d(sigma), pc.as2d(gy)
    (gx,) = pc.run_rowwise(
        _msrms_bwd_kernel, z2, out_shapes=[(z2.shape[1], z.dtype)],
        extra_inputs=(s2, g2),
    )
    return gx.reshape(z.shape)
