"""Mesa-like 8-bit activation quantization Pallas kernels (baseline).

Per-row symmetric int8 quantization of the saved activation: the forward
stores (q:int8, scale:f32 per row) instead of the f32 tensor; the backward
dequantizes before use.  This reproduces the comparator's memory (~8 bits
per element) *and* its throughput cost (extra quant/dequant passes), which
is the contrast the paper draws in Tables 1/7.
"""

import jax.numpy as jnp

from . import pallas_common as pc


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...]
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12)
    scale = amax / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    scale_ref[...] = scale


def _dequant_kernel(q_ref, scale_ref, y_ref):
    y_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...]


def quant(x):
    """x: [..., C] f32 -> (q int8 [..., C], scale f32 [..., 1])."""
    x2 = pc.as2d(x)
    q, scale = pc.run_rowwise(
        _quant_kernel, x2, out_shapes=[(x2.shape[1], jnp.int8), (1, jnp.float32)]
    )
    return q.reshape(x.shape), scale.reshape(*x.shape[:-1], 1)


def dequant(q, scale):
    q2, s2 = pc.as2d(q), pc.as2d(scale)
    (y,) = pc.run_rowwise(
        _dequant_kernel, q2, out_shapes=[(q2.shape[1], jnp.float32)],
        extra_inputs=(s2,),
    )
    return y.reshape(q.shape)
