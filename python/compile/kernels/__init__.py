"""L1: Pallas kernels for the paper memory hot-spots + pure-jnp oracle.

Modules: regelu2 / resilu2 (Approx-BP activations, 2-bit residuals),
msnorm (MS-LN / MS-RMSNorm, Algorithms 2-3), quant8 (Mesa baseline),
ref (oracle), coeffs (Appendix E constants).
"""
