"""ReGELU2 Pallas kernels (paper §4.2, Appendix E.1).

Forward: exact GELU, *plus* the 2-bit segment codes of the input against
the c* thresholds, packed 4-per-byte.  Backward: unpack codes in-register
(shift/mask — no dequantization pass) and multiply the upstream gradient by
the 4-entry slope table [0, a1, a1+a2, 1].

The forward stores only ``codes`` (2 bits/element) for backward — this is
the paper's entire memory saving for activation functions.
"""

import jax.numpy as jnp

from . import coeffs, pallas_common as pc

_SQRT_2 = 1.4142135623730951


def _gelu(x):
    from . import ref

    return ref.gelu(x)


def _encode_kernel_factory(c):
    c1, c2, c3 = c

    def kernel(x_ref, y_ref, packed_ref):
        x = x_ref[...]
        y_ref[...] = _gelu(x)
        code = (
            (x >= c1).astype(jnp.uint32)
            + (x >= c2).astype(jnp.uint32)
            + (x >= c3).astype(jnp.uint32)
        )
        # pack 4 lanes/byte: reshape (TR, C//4, 4); weights 1,4,16,64
        tr, cc = code.shape
        lanes = code.reshape(tr, cc // 4, 4)
        packed = (
            lanes[..., 0]
            + lanes[..., 1] * 4
            + lanes[..., 2] * 16
            + lanes[..., 3] * 64
        )
        packed_ref[...] = packed.astype(jnp.uint8)

    return kernel


def _decode_kernel_factory(a):
    # step-table as scalar constants: slope(code) = s0 + code>=1?(s1-s0)...
    s0, s1, s2, s3 = coeffs.slopes(a)

    def kernel(packed_ref, gy_ref, gx_ref):
        p = packed_ref[...].astype(jnp.uint32)
        tr, cq = p.shape
        lanes = jnp.stack(
            [p & 3, (p >> 2) & 3, (p >> 4) & 3, (p >> 6) & 3], axis=-1
        )
        codes = lanes.reshape(tr, cq * 4)
        # branch-free slope lookup from scalar table entries
        slopes = (
            s0
            + (codes >= 1).astype(jnp.float32) * (s1 - s0)
            + (codes >= 2).astype(jnp.float32) * (s2 - s1)
            + (codes >= 3).astype(jnp.float32) * (s3 - s2)
        )
        gx_ref[...] = gy_ref[...] * slopes

    return kernel


def fwd(x, a=coeffs.A_GELU, c=coeffs.C_GELU):
    """x: [..., C] with C % 4 == 0. Returns (gelu(x), packed_codes)."""
    x2 = pc.as2d(x)
    r, cc = x2.shape
    assert cc % 4 == 0, "feature dim must be divisible by 4 for 2-bit packing"
    y, packed = pc.run_rowwise(
        _encode_kernel_factory(c),
        x2,
        out_shapes=[(cc, x.dtype), (cc // 4, jnp.uint8)],
    )
    return y.reshape(x.shape), packed.reshape(*x.shape[:-1], cc // 4)


def bwd(packed, gy, a=coeffs.A_GELU):
    """packed: [..., C//4] uint8; gy: [..., C]. Returns gx."""
    gy2 = pc.as2d(gy)
    p2 = pc.as2d(packed)
    (gx,) = pc.run_rowwise(
        _decode_kernel_factory(a),
        p2,
        out_shapes=[(gy2.shape[1], gy.dtype)],
        extra_inputs=(gy2,),
    )
    return gx.reshape(gy.shape)
