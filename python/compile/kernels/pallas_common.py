"""Shared tiling helpers for the Pallas kernels.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's CUDA
kernels assign one threadblock per row-slab; here each grid step keeps one
(TR, C) slab resident in VMEM and streams slabs HBM→VMEM via BlockSpec.
``interpret=True`` everywhere — CPU-PJRT cannot execute Mosaic custom-calls,
so the real-TPU perf story is the VMEM/MXU estimate in EXPERIMENTS.md §Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Target VMEM residency per input slab (elements). 8 KiB-lanes friendly:
# rows are tiled so that TR*C stays below this; C itself is kept whole so
# rowwise reductions (norms) need no cross-block accumulation.
VMEM_SLAB_ELEMS = 1 << 16

INTERPRET = True  # CPU correctness path; flip only for a real TPU toolchain.


def row_tile(n_rows: int, n_cols: int) -> int:
    """Pick a row-tile size: power of two, slab fits VMEM budget."""
    tr = max(1, VMEM_SLAB_ELEMS // max(n_cols, 1))
    # round down to a power of two for clean lane alignment
    while tr & (tr - 1):
        tr &= tr - 1
    return max(1, min(tr, n_rows))


def pad_rows(x2d, tr: int):
    """Pad rows up to a multiple of tr. Returns (padded, original_rows)."""
    r = x2d.shape[0]
    rem = (-r) % tr
    if rem:
        x2d = jnp.pad(x2d, ((0, rem), (0, 0)))
    return x2d, r


def as2d(x):
    """Collapse leading dims: [..., C] -> [R, C]."""
    return x.reshape(-1, x.shape[-1])


def run_rowwise(kernel, x2d, out_shapes, extra_inputs=()):
    """Launch `kernel` over row tiles of x2d.

    out_shapes: list of (cols, dtype) — every output is [R, cols_i].
    extra_inputs: same-R 2D arrays tiled alongside x.
    """
    tr = row_tile(*x2d.shape)
    xp, r = pad_rows(x2d, tr)
    extras = [pad_rows(e, tr)[0] for e in extra_inputs]
    grid = (xp.shape[0] // tr,)

    in_specs = [pl.BlockSpec((tr, xp.shape[1]), lambda i: (i, 0))]
    for e in extras:
        in_specs.append(pl.BlockSpec((tr, e.shape[1]), lambda i: (i, 0)))
    out_specs = [pl.BlockSpec((tr, c), lambda i: (i, 0)) for c, _ in out_shapes]
    outs = [
        jax.ShapeDtypeStruct((xp.shape[0], c), d) for c, d in out_shapes
    ]
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=outs if len(outs) > 1 else outs[0],
        interpret=INTERPRET,
    )(xp, *extras)
    if not isinstance(res, (tuple, list)):
        res = (res,)
    return tuple(o[:r] for o in res)
