"""Pure-jnp oracle for every L1 kernel.

Everything in this file is the *reference semantics*: the Pallas kernels in
this package and the manual-backprop layers in ``layers.py`` are tested
against these functions (pytest + hypothesis in ``python/tests``).
"""

import jax
import jax.numpy as jnp

from . import coeffs

SQRT_2 = 1.4142135623730951


def erf(x):
    """erf from primitive HLO ops (Abramowitz–Stegun 7.1.26, |ε|≤1.5e-7
    ≈ f32 eps).

    jax ≥ 0.5 lowers ``jax.lax.erf`` to a dedicated `erf` HLO opcode that
    the xla_extension 0.5.1 text parser rejects — so the AOT path needs an
    erf composed of mul/add/exp only. 1.5e-7 is below f32 resolution over
    the whole range, so the GELU forward stays bit-faithful in practice.
    """
    a = (0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429)
    s = jnp.sign(x)
    z = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * z)
    poly = t * (a[0] + t * (a[1] + t * (a[2] + t * (a[3] + t * a[4]))))
    return s * (1.0 - poly * jnp.exp(-z * z))


# ---------------------------------------------------------------------------
# activations and their exact derivatives
# ---------------------------------------------------------------------------

def gelu(x):
    """Exact (erf-based) GELU — the paper's forward pass, eq. (40)."""
    return 0.5 * x * (1.0 + erf(x / SQRT_2))


def dgelu(x):
    """Exact GELU derivative (for the GELU baseline backward)."""
    cdf = 0.5 * (1.0 + erf(x / SQRT_2))
    pdf = jnp.exp(-0.5 * x * x) / jnp.sqrt(2.0 * jnp.pi)
    return cdf + x * pdf


def silu(x):
    """SiLU / swish, eq. (47)."""
    return x * jax.nn.sigmoid(x)


def dsilu(x):
    s = jax.nn.sigmoid(x)
    return s * (1.0 + x * (1.0 - s))


def relu(x):
    return jnp.maximum(x, 0.0)


def drelu(x):
    return (x > 0.0).astype(x.dtype)


def relu_comb(x, a, c):
    """h̃_{a,c}: the 3-ReLU combination, eq. (13) with k=2."""
    a1, a2 = a
    c1, c2, c3 = c
    return (
        a1 * jnp.maximum(x - c1, 0.0)
        + a2 * jnp.maximum(x - c2, 0.0)
        + (1.0 - a1 - a2) * jnp.maximum(x - c3, 0.0)
    )


def bucketize2(x, c):
    """2-bit segment code: #{thresholds below x} ∈ {0,1,2,3}."""
    c1, c2, c3 = c
    return (
        (x >= c1).astype(jnp.uint8)
        + (x >= c2).astype(jnp.uint8)
        + (x >= c3).astype(jnp.uint8)
    )


def drelu_comb_from_codes(codes, a):
    """Step-function derivative values from 2-bit codes (branch-free
    arithmetic instead of a 4-entry gather — vectorizes on CPU/VPU)."""
    s0, s1, s2, s3 = coeffs.slopes(a)
    c = codes
    return (
        s0
        + (c >= 1).astype(jnp.float32) * (s1 - s0)
        + (c >= 2).astype(jnp.float32) * (s2 - s1)
        + (c >= 3).astype(jnp.float32) * (s3 - s2)
    )


def drelu_comb(x, a, c):
    """Step-function derivative of h̃_{a,c} (direct, for testing)."""
    return drelu_comb_from_codes(bucketize2(x, c), a)


# ---------------------------------------------------------------------------
# 2-bit packing: 4 codes per uint8, little-endian within the byte
# ---------------------------------------------------------------------------

def pack2bit(codes):
    """codes: uint8 in {0..3}, flat length divisible by 4 -> packed uint8.

    PLANAR layout (perf: EXPERIMENTS.md §Perf L2-1): byte b holds elements
    {b, b+N/4, b+N/2, b+3N/4}. Packing/unpacking is then four full-width
    vector passes with no per-element interleaving — XLA CPU lowers it to
    straight-line vector code instead of the gather/transpose the
    4-consecutive-elements layout produced (2.1× faster fwd+bwd)."""
    c = codes.reshape(4, -1)
    packed = c[0] | (c[1] << 2) | (c[2] << 4) | (c[3] << 6)
    return packed.astype(jnp.uint8)


def pack1bit(bits):
    """bits: uint8 in {0,1}, flat length divisible by 8 -> packed uint8.

    Used by the ReLU baseline (1-bit sign residual, §4.2). Planar layout
    (see pack2bit)."""
    b = bits.reshape(8, -1)
    out = b[0]
    for k in range(1, 8):
        out = out | (b[k] << k)
    return out.astype(jnp.uint8)


def unpack1bit(packed, n):
    p = packed.reshape(-1)
    lanes = jnp.concatenate([(p >> k) & 1 for k in range(8)])
    return lanes[:n].astype(jnp.uint8)


def unpack2bit(packed, n):
    """Inverse of pack2bit (planar); returns flat uint8 codes, length n."""
    p = packed.reshape(-1)
    lanes = jnp.concatenate([p & 3, (p >> 2) & 3, (p >> 4) & 3,
                             (p >> 6) & 3])
    return lanes[:n].astype(jnp.uint8)


# ---------------------------------------------------------------------------
# normalization layers (rowwise over the last axis)
# ---------------------------------------------------------------------------

def ln_fwd(x, weight, bias, eps=1e-6):
    """Standard LayerNorm with affine. Returns (y, mean, rstd)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    return (xc * rstd) * weight + bias, mu, rstd


def ln_bwd(x, mu, rstd, weight, gy):
    """Standard LayerNorm backward from saved (x, mu, rstd)."""
    xhat = (x - mu) * rstd
    gxhat = gy * weight
    gw = jnp.sum(gy * xhat, axis=tuple(range(gy.ndim - 1)))
    gb = jnp.sum(gy, axis=tuple(range(gy.ndim - 1)))
    gx = rstd * (
        gxhat
        - jnp.mean(gxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(gxhat * xhat, axis=-1, keepdims=True)
    )
    return gx, gw, gb


def rms_fwd(x, weight, eps=1e-6):
    """Standard RMSNorm with affine scale. Returns (y, rstd)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(ms + eps)
    return x * rstd * weight, rstd


def rms_bwd(x, rstd, weight, gy):
    xhat = x * rstd
    gxhat = gy * weight
    gw = jnp.sum(gy * xhat, axis=tuple(range(gy.ndim - 1)))
    gx = rstd * (gxhat - xhat * jnp.mean(gxhat * xhat, axis=-1, keepdims=True))
    return gx, gw


def msln_fwd(x, eps=1e-6):
    """MS-LN forward (affine already merged into the next linear), eq. (18).

    Returns (z, sigma): z is the only tensor saved for backward (and it is
    shared with the following linear layer); sigma is one scalar per row.
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    sigma = jnp.sqrt(jnp.mean(xc * xc, axis=-1, keepdims=True) + eps)
    return xc / sigma, sigma


def msln_bwd(z, sigma, gy):
    """Algorithm 2: gx = σ⁻¹ (H − p⁻¹ z zᵀ) gy with H = I − p⁻¹ 1 1ᵀ."""
    hg = gy - jnp.mean(gy, axis=-1, keepdims=True)
    zg = jnp.mean(z * gy, axis=-1, keepdims=True)
    return (hg - z * zg) / sigma


def msrms_fwd(x, eps=1e-6):
    """MS-RMSNorm forward, Algorithm 3. Returns (z, sigma)."""
    sigma = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x / sigma, sigma


def msrms_bwd(z, sigma, gy):
    """Algorithm 3: gx = σ⁻¹ (I − p⁻¹ z zᵀ) gy."""
    zg = jnp.mean(z * gy, axis=-1, keepdims=True)
    return (gy - z * zg) / sigma


# ---------------------------------------------------------------------------
# Mesa-like 8-bit activation quantization (baseline comparator)
# ---------------------------------------------------------------------------

def quant8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequant8(q, scale):
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# attention (memory-linear: bwd recomputes the probs from q,k,v)
# ---------------------------------------------------------------------------

def attention_fwd(q, k, v, causal=False):
    """q,k,v: [B, H, N, D]. Returns o. Probs are NOT a residual."""
    d = q.shape[-1]
    logits = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(
        jnp.asarray(d, dtype=q.dtype)
    )
    if causal:
        n, m = logits.shape[-2:]
        mask = jnp.tril(jnp.ones((n, m), dtype=bool))
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhnm,bhmd->bhnd", probs, v)


def attention_bwd(q, k, v, go, causal=False):
    """Backward with prob recomputation (the FlashAttention memory shape)."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    logits = jnp.einsum("bhnd,bhmd->bhnm", q, k) * scale
    if causal:
        n, m = logits.shape[-2:]
        mask = jnp.tril(jnp.ones((n, m), dtype=bool))
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gv = jnp.einsum("bhnm,bhnd->bhmd", probs, go)
    gprobs = jnp.einsum("bhnd,bhmd->bhnm", go, v)
    # softmax vjp
    dot = jnp.sum(gprobs * probs, axis=-1, keepdims=True)
    glogits = probs * (gprobs - dot)
    gq = jnp.einsum("bhnm,bhmd->bhnd", glogits, k) * scale
    gk = jnp.einsum("bhnm,bhnd->bhmd", glogits, q) * scale
    return gq, gk, gv
