"""ReSiLU2 Pallas kernels (paper §4.2, Appendix E.2).

Forward: exact SiLU + packed 2-bit segment codes; backward: step-function
slope lookup. Same kernel structure as ReGELU2 with the SiLU coefficient
set — see ``regelu2.py`` for the packing layout.
"""

import jax
import jax.numpy as jnp

from . import coeffs, pallas_common as pc


def _encode_kernel_factory(c):
    c1, c2, c3 = c

    def kernel(x_ref, y_ref, packed_ref):
        x = x_ref[...]
        y_ref[...] = x * jax.nn.sigmoid(x)
        code = (
            (x >= c1).astype(jnp.uint32)
            + (x >= c2).astype(jnp.uint32)
            + (x >= c3).astype(jnp.uint32)
        )
        tr, cc = code.shape
        lanes = code.reshape(tr, cc // 4, 4)
        packed = (
            lanes[..., 0]
            + lanes[..., 1] * 4
            + lanes[..., 2] * 16
            + lanes[..., 3] * 64
        )
        packed_ref[...] = packed.astype(jnp.uint8)

    return kernel


def _decode_kernel_factory(a):
    s0, s1, s2, s3 = coeffs.slopes(a)

    def kernel(packed_ref, gy_ref, gx_ref):
        p = packed_ref[...].astype(jnp.uint32)
        tr, cq = p.shape
        lanes = jnp.stack(
            [p & 3, (p >> 2) & 3, (p >> 4) & 3, (p >> 6) & 3], axis=-1
        )
        codes = lanes.reshape(tr, cq * 4)
        slopes = (
            s0
            + (codes >= 1).astype(jnp.float32) * (s1 - s0)
            + (codes >= 2).astype(jnp.float32) * (s2 - s1)
            + (codes >= 3).astype(jnp.float32) * (s3 - s2)
        )
        gx_ref[...] = gy_ref[...] * slopes

    return kernel


def fwd(x, a=coeffs.A_SILU, c=coeffs.C_SILU):
    """x: [..., C] with C % 4 == 0. Returns (silu(x), packed_codes)."""
    x2 = pc.as2d(x)
    r, cc = x2.shape
    assert cc % 4 == 0, "feature dim must be divisible by 4 for 2-bit packing"
    y, packed = pc.run_rowwise(
        _encode_kernel_factory(c),
        x2,
        out_shapes=[(cc, x.dtype), (cc // 4, jnp.uint8)],
    )
    return y.reshape(x.shape), packed.reshape(*x.shape[:-1], cc // 4)


def bwd(packed, gy, a=coeffs.A_SILU):
    """packed: [..., C//4] uint8; gy: [..., C]. Returns gx."""
    gy2 = pc.as2d(gy)
    p2 = pc.as2d(packed)
    (gx,) = pc.run_rowwise(
        _decode_kernel_factory(a),
        p2,
        out_shapes=[(gy2.shape[1], gy.dtype)],
        extra_inputs=(gy2,),
    )
    return gx.reshape(gy.shape)
