"""Quasi-optimal ReLU-combination coefficients from the paper (Appendix E / I).

h̃_{a,c}(x) = a1·ReLU(x−c1) + a2·ReLU(x−c2) + (1−a1−a2)·ReLU(x−c3)

Its derivative is the 4-segment step function with slopes
    [0, a1, a1+a2, 1]   on segments split at (c1, c2, c3),
which is what ReGELU2/ReSiLU2 use as the backward pass while keeping the
exact GELU/SiLU forward.  Only the 2-bit segment index is stored for bwd.

The rust substrate (`rust/src/coeffs/`) re-derives these via simulated
annealing + adaptive Simpson integration; `exp appe` checks agreement.
"""

# Appendix E.1 — ReGELU2 (primitive-matching, adopted in the paper's code)
A_GELU = (-0.04922261145617846, 1.0979632065417297)
C_GELU = (-3.1858810036855245, -0.001178821281161997, 3.190832613414926)

# Appendix E.2 — ReSiLU2
A_SILU = (-0.04060357190528599, 1.080925428529668)
C_SILU = (-6.3050461001646445, -0.0008684942046214787, 6.325815242089708)

# Appendix I — ReGELU2-d (derivative-matching ablation, Table 6)
A_GELU_D = (0.32465931184406527, 0.34812875668739607)
C_GELU_D = (-0.4535743722857079, -0.0010587205574873046, 0.4487575313884231)


def slopes(a):
    """Step-function values per 2-bit segment code: [0, a1, a1+a2, 1]."""
    a1, a2 = a
    return (0.0, a1, a1 + a2, 1.0)


SLOPES_GELU = slopes(A_GELU)
SLOPES_SILU = slopes(A_SILU)
SLOPES_GELU_D = slopes(A_GELU_D)

BY_NAME = {
    "regelu2": (A_GELU, C_GELU),
    "resilu2": (A_SILU, C_SILU),
    "regelu2d": (A_GELU_D, C_GELU_D),
}
