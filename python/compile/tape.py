"""Residual tape: the explicit fwd→bwd ABI boundary.

Every tensor a layer saves for backward goes through ``Tape.save``.  The
tape's entries become the trailing outputs of ``fwd.hlo`` and the residual
inputs of ``bwd.hlo`` — so the bytes on the tape *are* the paper's
"activation memory", measured exactly by the rust coordinator.

Residual ``kind`` tags drive the per-module breakdown (Figure 2):
  linear_input | lora_u | act_full | act_codes | act_q8 | act_scale |
  norm_input | norm_stat | norm_shared | attn_qkv | gate_operand | head_input
"""

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass
class ResidualSpec:
    name: str
    kind: str
    module: str  # e.g. "block3.mlp.act" — for per-module accounting
    shape: tuple
    dtype: str
    bits_per_logical_elem: float  # for paper-style "units" reporting


class Tape:
    """Ordered residual store. fwd: save(); bwd: read by recorded index."""

    def __init__(self):
        self.vals = []
        self.specs = []

    def save(self, module, name, kind, arr, bits=None):
        idx = len(self.vals)
        self.vals.append(arr)
        if bits is None:
            bits = jnp.dtype(arr.dtype).itemsize * 8
        self.specs.append(
            ResidualSpec(
                name=f"{module}.{name}",
                kind=kind,
                module=module,
                shape=tuple(int(s) for s in arr.shape),
                dtype=str(arr.dtype),
                bits_per_logical_elem=float(bits),
            )
        )
        return idx

    def __len__(self):
        return len(self.vals)


class TapeReader:
    """bwd-side view: layers read residuals by the indices recorded in fwd."""

    def __init__(self, vals):
        self.vals = list(vals)

    def __getitem__(self, idx):
        return self.vals[idx]
