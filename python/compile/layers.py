"""L2: manually-backpropagated transformer layers with explicit residuals.

Every layer is written as ``fwd(P, tape, x) -> y`` / ``bwd(P, tr, gy) ->
(gx, {param_idx: grad})``.  What goes on the tape is *exactly* the paper's
activation-memory story:

  Linear  full    — saves its input x            (Fig 5 "+1")
          frozen  — saves nothing                (Fig 5 "\\")
          lora    — saves x and u = xAᵀ          (§3.2, eq. 5)
          lorafa  — saves only u                 (LoRA-FA, §3.2)
  Act     gelu/silu     — saves x (full tensor)  (Fig 5 "+4")
          regelu2/resilu2 — saves 2-bit codes    (Fig 5 "+0.5")
          relu          — saves 1-bit signs
          mesa8         — saves int8 x + scale   (Mesa baseline)
  Norm    ln/rms        — saves x (+ per-row stats)
          msln/msrms    — saves z shared with the next linear + per-row σ
          mesaln8       — saves int8 x + stats

Backward correctness is pytest-checked against ``jax.grad`` of the same
forward (exact variants) or of the ReLU-combination surrogate (Approx-BP
variants).
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import coeffs, ref
from .kernels import msnorm as k_msnorm
from .kernels import quant8 as k_quant8
from .kernels import regelu2 as k_regelu2
from .kernels import resilu2 as k_resilu2


# ---------------------------------------------------------------------------
# parameter registry
# ---------------------------------------------------------------------------

class ParamSpec:
    def __init__(self, name, shape, trainable, init):
        self.name = name
        self.shape = tuple(int(s) for s in shape)
        self.trainable = trainable
        self.init = init  # "zeros" | "ones" | "normal:<std>"

    def materialize(self, rng: np.random.RandomState):
        if self.init == "zeros":
            return np.zeros(self.shape, np.float32)
        if self.init == "ones":
            return np.ones(self.shape, np.float32)
        if self.init.startswith("normal:"):
            std = float(self.init.split(":", 1)[1])
            return (rng.randn(*self.shape) * std).astype(np.float32)
        raise ValueError(f"unknown init {self.init}")


class Alloc:
    """Assigns global parameter indices at model-build time."""

    def __init__(self):
        self.specs = []

    def add(self, name, shape, trainable, init):
        self.specs.append(ParamSpec(name, shape, trainable, init))
        return len(self.specs) - 1


def _as2d(x):
    return x.reshape(-1, x.shape[-1])


def _matgrad(gy, x):
    """gW for y = x @ W.T: [dout, din]."""
    return jnp.einsum("ro,ri->oi", _as2d(gy), _as2d(x))


# ---------------------------------------------------------------------------
# Linear with tuning modes
# ---------------------------------------------------------------------------

class Linear:
    MODES = ("full", "frozen", "lora", "lorafa")

    def __init__(self, alloc, module, din, dout, mode, bias=True,
                 lora_rank=4, lora_scale=1.0, init_std=0.02):
        assert mode in self.MODES
        self.module, self.mode, self.bias = module, mode, bias
        self.din, self.dout = din, dout
        self.lora_scale = lora_scale
        self.iw = alloc.add(f"{module}.W", (dout, din), mode == "full",
                            f"normal:{init_std}")
        self.ib = alloc.add(f"{module}.b", (dout,), mode == "full", "zeros") \
            if bias else None
        if mode in ("lora", "lorafa"):
            # LoRA: A ~ N(0, std), B = 0 so the adapter starts as identity.
            self.ia = alloc.add(f"{module}.lora_A", (lora_rank, din),
                                mode == "lora", f"normal:{init_std}")
            self.ib2 = alloc.add(f"{module}.lora_B", (dout, lora_rank),
                                 True, "zeros")

    def fwd(self, P, tape, x, shared_x_idx=None):
        W = P[self.iw]
        y = _as2d(x) @ W.T
        if self.bias:
            y = y + P[self.ib]
        y = y.reshape(*x.shape[:-1], self.dout)
        if self.mode in ("lora", "lorafa"):
            u = _as2d(x) @ P[self.ia].T
            u = u.reshape(*x.shape[:-1], -1)
            y = y + (self.lora_scale * (_as2d(u) @ P[self.ib2].T)
                     ).reshape(*x.shape[:-1], self.dout)
        # --- residual policy (the paper's Table/Fig 5 accounting) ---
        self._x_idx = None
        self._u_idx = None
        if self.mode == "full" or self.mode == "lora":
            if shared_x_idx is not None:
                self._x_idx = shared_x_idx  # share with MS-norm output
            else:
                self._x_idx = tape.save(self.module, "x", "linear_input", x)
        if self.mode in ("lora", "lorafa"):
            self._u_idx = tape.save(self.module, "u", "lora_u", u)
        return y

    def bwd(self, P, tr, gy):
        W = P[self.iw]
        grads = {}
        gx = (_as2d(gy) @ W).reshape(*gy.shape[:-1], self.din)
        if self.mode == "full":
            x = tr[self._x_idx]
            grads[self.iw] = _matgrad(gy, x)
            if self.bias:
                grads[self.ib] = jnp.sum(_as2d(gy), axis=0)
        if self.mode in ("lora", "lorafa"):
            u = tr[self._u_idx]
            B = P[self.ib2]
            gu = self.lora_scale * (_as2d(gy) @ B)
            grads[self.ib2] = self.lora_scale * _matgrad(gy, u)
            A = P[self.ia]
            if self.mode == "lora":
                x = tr[self._x_idx]
                grads[self.ia] = _matgrad(gu.reshape(*gy.shape[:-1], -1), x)
            gx = gx + (gu @ A).reshape(*gy.shape[:-1], self.din)
        return gx, grads


# ---------------------------------------------------------------------------
# Activation functions
# ---------------------------------------------------------------------------

class Activation:
    KINDS = ("gelu", "silu", "relu", "regelu2", "regelu2d", "resilu2",
             "mesa_gelu8", "mesa_silu8")

    def __init__(self, module, kind, use_pallas=False):
        assert kind in self.KINDS
        self.module, self.kind, self.use_pallas = module, kind, use_pallas

    def fwd(self, tape, x):
        k = self.kind
        if k in ("gelu", "mesa_gelu8"):
            y = ref.gelu(x)
        elif k in ("silu", "mesa_silu8"):
            y = ref.silu(x)
        elif k == "relu":
            y = ref.relu(x)
        elif k in ("regelu2", "regelu2d"):
            a, c = coeffs.BY_NAME[k]
            if self.use_pallas:
                y, packed = k_regelu2.fwd(x, a, c)
                self._res = tape.save(self.module, "codes", "act_codes",
                                      packed, bits=2.0)
                self._shape = x.shape
                return y
            y = ref.gelu(x)
        elif k == "resilu2":
            a, c = coeffs.BY_NAME[k]
            if self.use_pallas:
                y, packed = k_resilu2.fwd(x, a, c)
                self._res = tape.save(self.module, "codes", "act_codes",
                                      packed, bits=2.0)
                self._shape = x.shape
                return y
            y = ref.silu(x)

        self._shape = x.shape
        if k in ("gelu", "silu"):
            self._res = tape.save(self.module, "x", "act_full", x)
        elif k == "relu":
            signs = (x > 0).astype(jnp.uint8).reshape(-1)
            packed = ref.pack1bit(signs)
            self._res = tape.save(self.module, "signs", "act_codes",
                                  packed, bits=1.0)
        elif k in ("regelu2", "regelu2d", "resilu2"):
            a, c = coeffs.BY_NAME[k]
            codes = ref.bucketize2(x, c).reshape(-1)
            packed = ref.pack2bit(codes)
            self._res = tape.save(self.module, "codes", "act_codes",
                                  packed, bits=2.0)
        else:  # mesa 8-bit
            if self.use_pallas:
                q, scale = k_quant8.quant(x)
            else:
                # per-row ref quant (same semantics as the pallas kernel)
                x2 = _as2d(x)
                amax = jnp.maximum(jnp.max(jnp.abs(x2), axis=-1,
                                           keepdims=True), 1e-12)
                scale = (amax / 127.0).reshape(*x.shape[:-1], 1)
                q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            self._res = tape.save(self.module, "q", "act_q8", q, bits=8.0)
            self._res_scale = tape.save(self.module, "scale", "act_scale",
                                        scale)
        return y

    def bwd(self, tr, gy):
        k = self.kind
        if k == "gelu":
            return gy * ref.dgelu(tr[self._res])
        if k == "silu":
            return gy * ref.dsilu(tr[self._res])
        if k == "relu":
            n = int(np.prod(self._shape))
            signs = ref.unpack1bit(tr[self._res], n).reshape(self._shape)
            return gy * signs.astype(gy.dtype)
        if k in ("regelu2", "regelu2d", "resilu2"):
            a, _ = coeffs.BY_NAME[k]
            if self.use_pallas:
                dec = k_regelu2 if k.startswith("regelu") else k_resilu2
                packed = tr[self._res]
                return dec.bwd(packed, gy, a)
            n = int(np.prod(self._shape))
            codes = ref.unpack2bit(tr[self._res], n).reshape(self._shape)
            return gy * ref.drelu_comb_from_codes(codes, a)
        # mesa 8-bit: dequantize then exact derivative on the dequantized x
        q, scale = tr[self._res], tr[self._res_scale]
        xhat = q.astype(jnp.float32) * scale
        d = ref.dgelu(xhat) if k == "mesa_gelu8" else ref.dsilu(xhat)
        return gy * d


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

class Norm:
    KINDS = ("ln", "msln", "rms", "msrms", "mesa_ln8")

    def __init__(self, alloc, module, dim, kind, affine_trainable,
                 use_pallas=False, eps=1e-6):
        assert kind in self.KINDS
        self.module, self.kind, self.eps = module, kind, eps
        self.dim, self.use_pallas = dim, use_pallas
        self.affine_trainable = affine_trainable
        self.shared_out_idx = None  # set by fwd for MS variants
        if kind in ("ln", "mesa_ln8"):
            self.iw = alloc.add(f"{module}.w", (dim,), affine_trainable, "ones")
            self.ib = alloc.add(f"{module}.b", (dim,), affine_trainable, "zeros")
        elif kind == "rms":
            self.iw = alloc.add(f"{module}.w", (dim,), affine_trainable, "ones")
        # MS variants: affine merged into the following linear (eq. 17)

    def fwd(self, P, tape, x):
        k = self.kind
        self.shared_out_idx = None
        if k == "ln":
            y, mu, rstd = ref.ln_fwd(x, P[self.iw], P[self.ib], self.eps)
            self._rx = tape.save(self.module, "x", "norm_input", x)
            self._rmu = tape.save(self.module, "mu", "norm_stat", mu)
            self._rrs = tape.save(self.module, "rstd", "norm_stat", rstd)
            return y
        if k == "mesa_ln8":
            y, mu, rstd = ref.ln_fwd(x, P[self.iw], P[self.ib], self.eps)
            x2 = _as2d(x)
            amax = jnp.maximum(jnp.max(jnp.abs(x2), axis=-1, keepdims=True),
                               1e-12)
            scale = (amax / 127.0).reshape(*x.shape[:-1], 1)
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            self._rx = tape.save(self.module, "q", "act_q8", q, bits=8.0)
            self._rsc = tape.save(self.module, "scale", "act_scale", scale)
            self._rmu = tape.save(self.module, "mu", "norm_stat", mu)
            self._rrs = tape.save(self.module, "rstd", "norm_stat", rstd)
            return y
        if k == "rms":
            y, rstd = ref.rms_fwd(x, P[self.iw], self.eps)
            self._rx = tape.save(self.module, "x", "norm_input", x)
            self._rrs = tape.save(self.module, "rstd", "norm_stat", rstd)
            return y
        if k == "msln":
            z, sigma = (k_msnorm.msln_fwd(x, self.eps) if self.use_pallas
                        else ref.msln_fwd(x, self.eps))
        else:  # msrms
            z, sigma = (k_msnorm.msrms_fwd(x, self.eps) if self.use_pallas
                        else ref.msrms_fwd(x, self.eps))
        self._rz = tape.save(self.module, "z", "norm_shared", z)
        self._rs = tape.save(self.module, "sigma", "norm_stat", sigma)
        self.shared_out_idx = self._rz
        return z

    def bwd(self, P, tr, gy):
        k = self.kind
        grads = {}
        if k in ("ln", "mesa_ln8"):
            if k == "ln":
                x = tr[self._rx]
            else:
                x = tr[self._rx].astype(jnp.float32) * tr[self._rsc]
            mu, rstd = tr[self._rmu], tr[self._rrs]
            gx, gw, gb = ref.ln_bwd(x, mu, rstd, P[self.iw], gy)
            if self.affine_trainable:  # skip dead grads when frozen
                grads[self.iw], grads[self.ib] = gw, gb
            return gx, grads
        if k == "rms":
            x, rstd = tr[self._rx], tr[self._rrs]
            gx, gw = ref.rms_bwd(x, rstd, P[self.iw], gy)
            if self.affine_trainable:
                grads[self.iw] = gw
            return gx, grads
        z, sigma = tr[self._rz], tr[self._rs]
        if k == "msln":
            gx = (k_msnorm.msln_bwd(z, sigma, gy) if self.use_pallas
                  else ref.msln_bwd(z, sigma, gy))
        else:
            gx = (k_msnorm.msrms_bwd(z, sigma, gy) if self.use_pallas
                  else ref.msrms_bwd(z, sigma, gy))
        return gx, grads
