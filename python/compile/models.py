"""Model assembly: ViT-style, LLaMA-style, RoBERTa-style stacks.

``Model`` exposes the two functions that become the AOT artifacts:

  fwd(P, x, y)            -> (loss, metric, *residuals)
  bwd(P, residuals, x, y) -> tuple of grads for trainable params (in order)

plus a pure-autodiff reference ``loss_ref`` used by the pytest gradient
checks (exact variants must match jax.grad; Approx-BP variants must match
jax.grad of the ReLU-combination surrogate model).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import blocks as B
from .layers import Alloc
from .tape import Tape, TapeReader


@dataclasses.dataclass
class ModelCfg:
    arch: str = "vit"            # vit | llama | roberta
    dim: int = 128
    depth: int = 4
    n_heads: int = 4
    mlp_ratio: float = 4.0
    n_tokens: int = 64           # patches (vit) or sequence length (llama)
    patch_dim: int = 48          # vit: flattened patch size
    n_classes: int = 10          # vit/roberta
    vocab: int = 256             # llama/roberta
    tuning: str = "lora_qv"      # full | lora_qv | lora_all | lorafa_qv | lorafa_all | frozen
    activation: str = "gelu"     # see layers.Activation.KINDS
    norm: str = "ln"             # see layers.Norm.KINDS
    lora_rank: int = 4
    use_pallas: bool = False
    batch: int = 8
    lm_head_trainable: bool = False
    ckpt: bool = False           # gradient checkpointing baseline (Fig 1)

    @property
    def hidden(self):
        return int(self.dim * self.mlp_ratio)


class Model:
    def __init__(self, cfg: ModelCfg):
        self.cfg = cfg
        alloc = Alloc()
        self.blocks = []
        act, norm, tun, r, up = (cfg.activation, cfg.norm, cfg.tuning,
                                 cfg.lora_rank, cfg.use_pallas)
        if cfg.arch in ("vit", "roberta"):
            if cfg.arch == "vit":
                self.embed = B.PatchEmbed(alloc, "embed", cfg.patch_dim,
                                          cfg.dim, cfg.n_tokens,
                                          trainable=(tun == "full"))
            else:
                self.embed = B.TokenEmbed(alloc, "embed", cfg.vocab, cfg.dim,
                                          trainable=(tun == "full"))
            for i in range(cfg.depth):
                self.blocks.append(B.AttnBlock(
                    alloc, f"block{i}.attn", cfg.dim, cfg.n_heads, tun,
                    norm, causal=False, lora_rank=r, use_pallas=up))
                self.blocks.append(B.MlpBlock(
                    alloc, f"block{i}.mlp", cfg.dim, cfg.hidden, tun, norm,
                    act, lora_rank=r, use_pallas=up))
            self.head = B.ClassifierHead(alloc, "head", cfg.dim,
                                         cfg.n_classes, tun, norm, up)
        elif cfg.arch == "llama":
            self.embed = B.TokenEmbed(alloc, "embed", cfg.vocab, cfg.dim,
                                      trainable=(tun == "full"))
            for i in range(cfg.depth):
                self.blocks.append(B.AttnBlock(
                    alloc, f"block{i}.attn", cfg.dim, cfg.n_heads, tun,
                    norm, causal=True, lora_rank=r, use_pallas=up,
                    qkv_bias=False))
                self.blocks.append(B.SwiGluBlock(
                    alloc, f"block{i}.mlp", cfg.dim, cfg.hidden, tun, norm,
                    act, lora_rank=r, use_pallas=up))
            self.head = B.LmHead(alloc, "head", cfg.dim, cfg.vocab, tun,
                                 norm, cfg.lm_head_trainable, up)
        else:
            raise ValueError(cfg.arch)
        self.param_specs = alloc.specs
        self.trainable_idx = [i for i, s in enumerate(self.param_specs)
                              if s.trainable]

    # -- batch specs ------------------------------------------------------

    def batch_spec(self):
        c = self.cfg
        if c.arch == "vit":
            return (jax.ShapeDtypeStruct((c.batch, c.n_tokens, c.patch_dim),
                                         jnp.float32),
                    jax.ShapeDtypeStruct((c.batch,), jnp.int32))
        if c.arch == "roberta":
            return (jax.ShapeDtypeStruct((c.batch, c.n_tokens), jnp.int32),
                    jax.ShapeDtypeStruct((c.batch,), jnp.int32))
        return (jax.ShapeDtypeStruct((c.batch, c.n_tokens), jnp.int32),
                jax.ShapeDtypeStruct((c.batch, c.n_tokens), jnp.int32))

    # -- the two AOT entry points -----------------------------------------

    def fwd(self, P, x, y):
        tape = Tape()
        h = self.embed.fwd(P, tape, x)
        if self.cfg.ckpt:
            # gradient-checkpointing baseline: save only block inputs; the
            # inner residuals go to a throwaway tape and are recomputed in
            # bwd (Chen et al. 2016 — "+CKPT" arm of Figure 1).
            self._blk_in = []
            for blk in self.blocks:
                self._blk_in.append(
                    tape.save(blk.module, "blk_in", "ckpt_input", h))
                h = blk.fwd(P, Tape(), h)
        else:
            for blk in self.blocks:
                h = blk.fwd(P, tape, h)
        loss, metric = self.head.fwd(P, tape, h, y)
        self.tape_specs = tape.specs
        return (loss, metric, *tape.vals)

    def bwd(self, P, residuals, x, y):
        """Requires fwd to have been *traced* first (records tape indices)."""
        tr = TapeReader(residuals)
        grads = {}
        gh, g = self.head.bwd(P, tr, y)
        grads.update(g)
        if self.cfg.ckpt:
            for bi, blk in reversed(list(zip(self._blk_in, self.blocks))):
                local = Tape()
                blk.fwd(P, local, tr[bi])  # recompute inner residuals
                gh, g = blk.bwd(P, TapeReader(local.vals), gh)
                grads.update(g)
        else:
            for blk in reversed(self.blocks):
                gh, g = blk.bwd(P, tr, gh)
                grads.update(g)
        if isinstance(self.embed, B.TokenEmbed):
            _, g = self.embed.bwd(P, tr, gh, x)
        else:
            _, g = self.embed.bwd(P, tr, gh)
        grads.update(g)
        out = []
        for i in self.trainable_idx:
            if i in grads:
                out.append(grads[i])
            else:  # trainable param unused this config — zero grad
                out.append(jnp.zeros(self.param_specs[i].shape, jnp.float32))
        return tuple(out)

    # -- pure-autodiff reference (for tests) ------------------------------

    def loss_ref(self, P, x, y):
        loss, _metric, *_res = self.fwd(P, x, y)
        return loss

    def init_params(self, seed=0):
        rng = np.random.RandomState(seed)
        return [s.materialize(rng) for s in self.param_specs]

    def merge_map(self):
        """Norm→linear affine-merge relationships (eq. 17), for the rust
        checkpoint converter: which linears absorb which norm's (α, β) when
        converting an LN/RMS checkpoint to an MS-LN/MS-RMSNorm model."""
        out = []
        for blk in self.blocks:
            if isinstance(blk, B.AttnBlock):
                out.append({"norm": blk.norm.module,
                            "linears": [blk.q.module, blk.k.module,
                                        blk.v.module]})
            elif isinstance(blk, B.SwiGluBlock):
                out.append({"norm": blk.norm.module,
                            "linears": [blk.fc1.module, blk.fc2.module]})
            elif isinstance(blk, B.MlpBlock):
                out.append({"norm": blk.norm.module,
                            "linears": [blk.fc1.module]})
        if hasattr(self.head, "norm"):
            if isinstance(self.head, B.LmHead):
                out.append({"norm": self.head.norm.module,
                            "linears": [self.head.fc.module]})
            # ClassifierHead: norm output is mean-pooled before the fc, so
            # the affine cannot be merged into fc directly; the pooled mean
            # commutes with diag(α) — we merge there too.
            else:
                out.append({"norm": self.head.norm.module,
                            "linears": [self.head.fc.module]})
        return out


def surrogate(cfg: ModelCfg) -> "Model":
    """The Approx-BP surrogate f̃: same config but with h̃_{a,c} forwards.

    Used by the gradient tests: our manual bwd for ReGELU2/ReSiLU2 must
    equal jax.grad of THIS model (not of the exact-GELU model).
    """
    import copy

    from .kernels import coeffs, ref
    from . import layers

    scfg = copy.deepcopy(cfg)
    m = Model(scfg)

    # monkeypatch activation forwards to the ReLU combination
    for blk in m.blocks:
        act = getattr(blk, "act", None)
        if act is not None and act.kind in coeffs.BY_NAME:
            a, c = coeffs.BY_NAME[act.kind]

            def make_fwd(act, a, c):
                def fwd(tape, x):
                    act._shape = x.shape
                    codes = ref.bucketize2(x, c).reshape(-1)
                    act._res = tape.save(act.module, "codes", "act_codes",
                                         ref.pack2bit(codes), bits=2.0)
                    return ref.relu_comb(x, a, c)
                return fwd

            act.fwd = make_fwd(act, a, c)
    return m
