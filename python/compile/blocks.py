"""Transformer blocks with the paper's residual-sharing wiring.

The MS-norm → linear sharing (Prop 5.1) is routed here: when the norm is a
MS variant *and* the following linear saves its input (full/lora modes),
the linear reuses the norm's saved ``z`` instead of saving its own copy.
LoRA-FA linears save only ``u = xAᵀ`` (condition 3 fails — the paper's
reason MS-LN does not help LoRA-FA).
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .layers import Activation, Linear, Norm, _as2d, _matgrad


def _split_heads(x, n_heads):
    b, n, c = x.shape
    return x.reshape(b, n, n_heads, c // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, n, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * d)


def linear_mode(which, tuning):
    """Map (projection, tuning method) -> Linear mode.

    tuning ∈ {full, lora_qv, lora_all, lorafa_qv, lorafa_all, frozen}.
    `which` ∈ {q, k, v, proj, fc} — q/v adapted in *_qv; everything in *_all.
    """
    if tuning == "full":
        return "full"
    if tuning == "frozen":
        return "frozen"
    adapt = {"lora_qv": ("q", "v"), "lorafa_qv": ("q", "v")}.get(
        tuning, ("q", "k", "v", "proj", "fc"))
    kind = "lorafa" if tuning.startswith("lorafa") else "lora"
    return kind if which in adapt else "frozen"


class AttnBlock:
    """Pre-norm multi-head self-attention block (ViT / LLaMA / RoBERTa)."""

    def __init__(self, alloc, module, dim, n_heads, tuning, norm_kind,
                 causal=False, lora_rank=4, use_pallas=False, qkv_bias=True):
        self.module, self.n_heads, self.causal = module, n_heads, causal
        self.norm = Norm(alloc, f"{module}.norm", dim, norm_kind,
                         affine_trainable=(tuning == "full"),
                         use_pallas=use_pallas)
        mk = lambda which, name: Linear(
            alloc, f"{module}.{name}", dim, dim,
            linear_mode(which, tuning), bias=qkv_bias,
            lora_rank=lora_rank)
        self.q, self.k, self.v = mk("q", "q"), mk("k", "k"), mk("v", "v")
        self.proj = mk("proj", "proj")

    def fwd(self, P, tape, x):
        z = self.norm.fwd(P, tape, x)
        # q/k/v consume the same tensor z: like pytorch's refcounted saved
        # tensors, z is stored ONCE and shared between them (and with the
        # MS-norm output when the norm is memory-sharing). The MS-BP win is
        # that the *norm input* x is not stored at all.
        sh = self.norm.shared_out_idx
        q = self.q.fwd(P, tape, z, shared_x_idx=sh)
        sh = sh if sh is not None else self.q._x_idx
        k = self.k.fwd(P, tape, z, shared_x_idx=sh)
        sh = sh if sh is not None else self.k._x_idx
        v = self.v.fwd(P, tape, z, shared_x_idx=sh)
        self._rq = tape.save(self.module, "q", "attn_qkv", q)
        self._rk = tape.save(self.module, "k", "attn_qkv", k)
        self._rv = tape.save(self.module, "v", "attn_qkv", v)
        o = ref.attention_fwd(
            _split_heads(q, self.n_heads), _split_heads(k, self.n_heads),
            _split_heads(v, self.n_heads), causal=self.causal)
        o = _merge_heads(o)
        y = self.proj.fwd(P, tape, o)
        return x + y

    def bwd(self, P, tr, gy):
        grads = {}
        go, g = self.proj.bwd(P, tr, gy)
        grads.update(g)
        q, k, v = tr[self._rq], tr[self._rk], tr[self._rv]
        gq, gk, gv = ref.attention_bwd(
            _split_heads(q, self.n_heads), _split_heads(k, self.n_heads),
            _split_heads(v, self.n_heads),
            _split_heads(go, self.n_heads), causal=self.causal)
        gz = jnp.zeros_like(gy)
        for lin, gh in ((self.q, gq), (self.k, gk), (self.v, gv)):
            gx, g = lin.bwd(P, tr, _merge_heads(gh))
            grads.update(g)
            gz = gz + gx
        gxn, g = self.norm.bwd(P, tr, gz)
        grads.update(g)
        return gy + gxn, grads


class MlpBlock:
    """Pre-norm ViT/RoBERTa MLP: norm → fc1 → act → fc2, residual add."""

    def __init__(self, alloc, module, dim, hidden, tuning, norm_kind,
                 act_kind, lora_rank=4, use_pallas=False):
        self.module = module
        self.norm = Norm(alloc, f"{module}.norm", dim, norm_kind,
                         affine_trainable=(tuning == "full"),
                         use_pallas=use_pallas)
        self.fc1 = Linear(alloc, f"{module}.fc1", dim, hidden,
                          linear_mode("fc", tuning), lora_rank=lora_rank)
        self.act = Activation(f"{module}.act", act_kind, use_pallas)
        self.fc2 = Linear(alloc, f"{module}.fc2", hidden, dim,
                          linear_mode("fc", tuning), lora_rank=lora_rank)

    def fwd(self, P, tape, x):
        z = self.norm.fwd(P, tape, x)
        h = self.fc1.fwd(P, tape, z, shared_x_idx=self.norm.shared_out_idx)
        h = self.act.fwd(tape, h)
        y = self.fc2.fwd(P, tape, h)
        return x + y

    def bwd(self, P, tr, gy):
        grads = {}
        gh, g = self.fc2.bwd(P, tr, gy)
        grads.update(g)
        gh = self.act.bwd(tr, gh)
        gz, g = self.fc1.bwd(P, tr, gh)
        grads.update(g)
        gxn, g = self.norm.bwd(P, tr, gz)
        grads.update(g)
        return gy + gxn, grads


class SwiGluBlock:
    """LLaMA MLP: norm → (up=fc1, gate=fc2) → silu(gate)*up → fc3 (Fig 6)."""

    def __init__(self, alloc, module, dim, hidden, tuning, norm_kind,
                 act_kind, lora_rank=4, use_pallas=False):
        self.module = module
        self.norm = Norm(alloc, f"{module}.norm", dim, norm_kind,
                         affine_trainable=(tuning == "full"),
                         use_pallas=use_pallas)
        mode = linear_mode("fc", tuning)
        self.fc1 = Linear(alloc, f"{module}.fc1", dim, hidden, mode,
                          bias=False, lora_rank=lora_rank)  # up
        self.fc2 = Linear(alloc, f"{module}.fc2", dim, hidden, mode,
                          bias=False, lora_rank=lora_rank)  # gate
        self.act = Activation(f"{module}.act", act_kind, use_pallas)
        self.fc3 = Linear(alloc, f"{module}.fc3", hidden, dim, mode,
                          bias=False, lora_rank=lora_rank)  # down

    def fwd(self, P, tape, x):
        z = self.norm.fwd(P, tape, x)
        # fc1/fc2 share the stored z (refcount semantics, as in AttnBlock)
        sh = self.norm.shared_out_idx
        up = self.fc1.fwd(P, tape, z, shared_x_idx=sh)
        sh = sh if sh is not None else self.fc1._x_idx
        gate = self.fc2.fwd(P, tape, z, shared_x_idx=sh)
        s = self.act.fwd(tape, gate)
        # gate multiply: both operands are residuals (Fig 6 "+5.4")
        self._rs = tape.save(self.module, "x_silu", "gate_operand", s)
        self._rup = tape.save(self.module, "x_fc1", "gate_operand", up)
        h = s * up
        y = self.fc3.fwd(P, tape, h)
        return x + y

    def bwd(self, P, tr, gy):
        grads = {}
        gh, g = self.fc3.bwd(P, tr, gy)
        grads.update(g)
        s, up = tr[self._rs], tr[self._rup]
        gs = gh * up
        gup = gh * s
        ggate = self.act.bwd(tr, gs)
        gz = jnp.zeros_like(gy)
        for lin, gg in ((self.fc1, gup), (self.fc2, ggate)):
            gx, g = lin.bwd(P, tr, gg)
            grads.update(g)
            gz = gz + gx
        gxn, g = self.norm.bwd(P, tr, gz)
        grads.update(g)
        return gy + gxn, grads


# ---------------------------------------------------------------------------
# input adapters and heads
# ---------------------------------------------------------------------------

class PatchEmbed:
    """ViT input: pre-patchified x [B, N, P] → linear → + pos-emb."""

    def __init__(self, alloc, module, patch_dim, dim, n_tokens, trainable):
        self.module = module
        self.proj = Linear(alloc, f"{module}.proj", patch_dim, dim,
                           "full" if trainable else "frozen")
        self.ipos = alloc.add(f"{module}.pos", (1, n_tokens, dim),
                              trainable, "normal:0.02")

    def fwd(self, P, tape, x):
        return self.proj.fwd(P, tape, x) + P[self.ipos]

    def bwd(self, P, tr, gy):
        _, grads = self.proj.bwd(P, tr, gy)
        spec_trainable = self.proj.mode == "full"
        if spec_trainable:
            grads[self.ipos] = jnp.sum(gy, axis=0, keepdims=True)
        return None, grads


class TokenEmbed:
    """LM input: tokens [B, T] i32 → table lookup."""

    def __init__(self, alloc, module, vocab, dim, trainable):
        self.module, self.vocab, self.trainable = module, vocab, trainable
        self.itab = alloc.add(f"{module}.table", (vocab, dim), trainable,
                              "normal:0.02")

    def fwd(self, P, tape, tokens):
        self._tokens_shape = tokens.shape
        return P[self.itab][tokens]

    def bwd(self, P, tr, gy, tokens):
        grads = {}
        if self.trainable:
            flat = tokens.reshape(-1)
            g2 = gy.reshape(-1, gy.shape[-1])
            grads[self.itab] = jnp.zeros(
                P[self.itab].shape, gy.dtype).at[flat].add(g2)
        return None, grads


class ClassifierHead:
    """Final norm → mean-pool → linear → softmax CE (ViT / RoBERTa)."""

    def __init__(self, alloc, module, dim, n_classes, tuning, norm_kind,
                 use_pallas=False):
        # the classifier itself is always trainable in fine-tuning
        self.module = module
        self.norm = Norm(alloc, f"{module}.norm", dim, norm_kind,
                         affine_trainable=(tuning == "full"),
                         use_pallas=use_pallas)
        self.fc = Linear(alloc, f"{module}.fc", dim, n_classes, "full")

    def fwd(self, P, tape, x, y):
        z = self.norm.fwd(P, tape, x)
        self._n_tokens = x.shape[1]
        pooled = jnp.mean(z, axis=1)
        # head input: with MS-norm, `z` is already on the tape but pooled is
        # a reduction of it — the pooled vector is tiny, save it directly.
        logits = self.fc.fwd(P, tape, pooled)
        self._rlogits = tape.save(self.module, "logits", "head_input", logits)
        logp = logits - jax.nn.logsumexp(logits, axis=-1, keepdims=True)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, acc

    def bwd(self, P, tr, y):
        logits = tr[self._rlogits]
        b = logits.shape[0]
        p = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(y, logits.shape[-1], dtype=logits.dtype)
        glogits = (p - onehot) / b
        gpooled, grads = self.fc.bwd(P, tr, glogits)
        gz = jnp.broadcast_to(
            gpooled[:, None, :] / self._n_tokens,
            (b, self._n_tokens, gpooled.shape[-1]))
        gx, g = self.norm.bwd(P, tr, gz)
        grads.update(g)
        return gx, grads


class LmHead:
    """Final norm → linear → next-token CE (LLaMA-style)."""

    def __init__(self, alloc, module, dim, vocab, tuning, norm_kind,
                 head_trainable=False, use_pallas=False):
        self.module = module
        self.norm = Norm(alloc, f"{module}.norm", dim, norm_kind,
                         affine_trainable=(tuning == "full"),
                         use_pallas=use_pallas)
        self.fc = Linear(alloc, f"{module}.fc", dim, vocab,
                         "full" if head_trainable else "frozen", bias=False)

    def fwd(self, P, tape, x, targets):
        z = self.norm.fwd(P, tape, x)
        if self.fc.mode == "frozen" and self.norm.shared_out_idx is None:
            # frozen head does not save z; but bwd needs it to push grads
            # through the norm — save it here (counted honestly).
            self._rz = tape.save(self.module, "z", "head_input", z)
        else:
            self._rz = self.norm.shared_out_idx
        logits = self.fc.fwd(P, tape, z,
                             shared_x_idx=self.norm.shared_out_idx)
        self._rlogits = tape.save(self.module, "logits", "head_input", logits)
        logp = logits - jax.nn.logsumexp(logits, axis=-1, keepdims=True)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        loss = jnp.mean(nll)
        acc = jnp.mean(
            (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
        return loss, acc

    def bwd(self, P, tr, targets):
        logits = tr[self._rlogits]
        n = logits.shape[0] * logits.shape[1]
        p = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
        glogits = (p - onehot) / n
        gz, grads = self.fc.bwd(P, tr, glogits)
        gx, g = self.norm.bwd(P, tr, gz)
        grads.update(g)
        return gx, grads
